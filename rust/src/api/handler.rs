//! The one place API requests are executed.
//!
//! [`ApiHandler`] owns the session state — the [`AnalysisCache`] every op
//! runs against and the lazily-created worker pool `batch` fans out over —
//! and [`execute`] is the pure per-request dispatch the pool's workers
//! share with the inline path. The CLI (`main.rs`), the stdio service
//! (`coordinator::service::serve_stdio`) and the worker pool all delegate
//! here; none of them parses or assembles wire JSON of their own.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::coordinator::service::{Coordinator, Job, JobResult};
use crate::live::{Monitor, MonitorOpts};
use crate::model::spec::parse_workflow;
use crate::runtime::cache::AnalysisCache;
use crate::runtime::sweep::{FixedWorkflow, SweepBatch, SweepError, SweepModel};
use crate::sense::SenseOpts;
use crate::solver::SolverOpts;
use crate::trace::{
    assemble, calibrate, parse_io_log, parse_tsv, replay, CalibrateOpts, CalibratedWorkflow,
};
use crate::util::par::num_threads;
use crate::util::Json;
use crate::workflow::engine::analyze_fixpoint_cached;
use crate::workflow::scenario::{GenomicsScenario, Perturbation, VideoScenario};

use super::error::{ApiError, ErrorCode};
use super::request::{decode_line, Request, WorkflowSel};
use super::response::{
    encode, AnalyzeResult, CalibrateResult, MonitorResult, Response, ScheduleRow, SegmentRow,
    StatsSnapshot, SweepResult,
};

/// Global service counters behind the `stats` op. A multi-session server
/// shares one instance across every session handler
/// ([`ApiHandler::for_session_with_stats`]); CLI and single-session stdio
/// handlers own a private one. All counters are atomics — a `stats` read
/// races live traffic by design and must never block it.
pub struct ServiceStats {
    start: Instant,
    sessions_open: AtomicU64,
    sessions_total: AtomicU64,
    inflight: AtomicU64,
    overloaded: AtomicU64,
    /// Completed-request totals keyed by wire op name (`stats` itself is
    /// not counted).
    ops: Mutex<BTreeMap<String, u64>>,
}

impl Default for ServiceStats {
    fn default() -> Self {
        Self::new()
    }
}

impl ServiceStats {
    pub fn new() -> ServiceStats {
        ServiceStats {
            start: Instant::now(),
            sessions_open: AtomicU64::new(0),
            sessions_total: AtomicU64::new(0),
            inflight: AtomicU64::new(0),
            overloaded: AtomicU64::new(0),
            ops: Mutex::new(BTreeMap::new()),
        }
    }

    /// A session attached (socket transports call this on accept).
    pub fn session_opened(&self) {
        self.sessions_open.fetch_add(1, Ordering::Relaxed);
        self.sessions_total.fetch_add(1, Ordering::Relaxed);
    }

    /// A session detached. Saturating: a stray double-close must not wrap
    /// the gauge.
    pub fn session_closed(&self) {
        let _ = self
            .sessions_open
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
    }

    fn begin(&self) {
        self.inflight.fetch_add(1, Ordering::Relaxed);
    }

    fn finish(&self, op: &'static str, outcome: &Result<Response, ApiError>) {
        let _ = self
            .inflight
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
        if matches!(outcome, Err(e) if e.code == ErrorCode::Overloaded) {
            self.overloaded.fetch_add(1, Ordering::Relaxed);
        }
        let mut ops = self.ops.lock().unwrap_or_else(|e| e.into_inner());
        *ops.entry(op.to_string()).or_insert(0) += 1;
    }

    /// Point-in-time snapshot. `mask` zeroes every time-varying field
    /// (uptime, counters, per-op totals) so the response bytes are
    /// reproducible — the conformance corpus relies on it.
    pub fn snapshot(&self, mask: bool) -> StatsSnapshot {
        if mask {
            return StatsSnapshot::default();
        }
        StatsSnapshot {
            uptime_secs: self.start.elapsed().as_secs_f64(),
            sessions_open: self.sessions_open.load(Ordering::Relaxed),
            sessions_total: self.sessions_total.load(Ordering::Relaxed),
            inflight: self.inflight.load(Ordering::Relaxed),
            overloaded: self.overloaded.load(Ordering::Relaxed),
            ops: self.ops.lock().unwrap_or_else(|e| e.into_inner()).clone(),
        }
    }
}

/// Where a handler's requests run.
enum PoolMode {
    /// CLI / single-session stdio: non-batch ops execute inline with the
    /// machine's full solver fan-out; a private pool is created on the
    /// first `batch` and kept for the handler's lifetime.
    Lazy(Mutex<Option<Arc<Coordinator>>>),
    /// One session of the multi-session server: every op is admitted
    /// through the shared pool's bounded queue (a full queue returns
    /// `overloaded` instead of blocking), so tenants compete for workers
    /// instead of oversubscribing the machine.
    Shared(Arc<Coordinator>),
}

/// Session-stateful API front end: one analysis cache (so repeat requests
/// are answered incrementally, per the paper's §7 "repeatedly executed
/// online" deployment) and a [`PoolMode`] saying where requests run.
pub struct ApiHandler {
    cache: Arc<AnalysisCache>,
    threads: usize,
    pool: PoolMode,
    /// The session's live monitor, if one is open (`docs/LIVE.md`). At
    /// most one per session; monitor ops always execute inline — the
    /// worker pool is stateless by design, so session state cannot (and
    /// must not) travel through it.
    monitor: Mutex<Option<Monitor>>,
    /// Global counters behind the `stats` op — the server's shared
    /// instance in session mode, else private to this handler.
    stats: Arc<ServiceStats>,
}

impl Default for ApiHandler {
    fn default() -> Self {
        Self::new()
    }
}

impl ApiHandler {
    pub fn new() -> ApiHandler {
        ApiHandler::with_threads(num_threads())
    }

    /// Handler whose `batch` pool has exactly `threads` workers.
    pub fn with_threads(threads: usize) -> ApiHandler {
        ApiHandler {
            cache: Arc::new(AnalysisCache::new()),
            threads: threads.max(1),
            pool: PoolMode::Lazy(Mutex::new(None)),
            monitor: Mutex::new(None),
            stats: Arc::new(ServiceStats::new()),
        }
    }

    /// A handler for one session of a multi-tenant server: `cache` is the
    /// session's own (typically quota-bounded) cache, and every op runs
    /// on the shared `pool` under its admission control.
    pub fn for_session(pool: Arc<Coordinator>, cache: Arc<AnalysisCache>) -> ApiHandler {
        Self::for_session_with_stats(pool, cache, Arc::new(ServiceStats::new()))
    }

    /// [`ApiHandler::for_session`] with the server's shared
    /// [`ServiceStats`], so every session's requests aggregate into the
    /// same global counters and any session's `stats` op sees the whole
    /// server.
    pub fn for_session_with_stats(
        pool: Arc<Coordinator>,
        cache: Arc<AnalysisCache>,
        stats: Arc<ServiceStats>,
    ) -> ApiHandler {
        ApiHandler {
            cache,
            threads: 1,
            pool: PoolMode::Shared(pool),
            monitor: Mutex::new(None),
            stats,
        }
    }

    /// The session-lifetime analysis cache every op runs against.
    pub fn cache(&self) -> &Arc<AnalysisCache> {
        &self.cache
    }

    /// Handle one typed request. `batch` fans out over the worker pool;
    /// other ops execute inline ([`PoolMode::Lazy`]) or as one pool job
    /// ([`PoolMode::Shared`]).
    pub fn handle(&self, req: &Request) -> Result<Response, ApiError> {
        // `stats` reads handler/server state, so it answers inline before
        // any pool dispatch; it does not count itself in the op totals
        if let Request::Stats { mask } = req {
            return Ok(Response::Stats(self.stats.snapshot(*mask)));
        }
        self.stats.begin();
        let outcome = match req {
            Request::Batch { requests } => self.handle_batch(requests),
            // monitor ops mutate session state, so they run inline in
            // both pool modes — a pool worker only ever sees pure requests
            Request::MonitorOpen {
                workflow,
                tol,
                bands,
            } => self.monitor_open(workflow, *tol, *bands),
            Request::MonitorFeed { tsv, io } => {
                self.monitor_feed(tsv.as_deref(), io.as_deref())
            }
            Request::MonitorStatus { close } => self.monitor_status(*close),
            other => match &self.pool {
                PoolMode::Shared(pool) => self.dispatch_one(pool, other),
                PoolMode::Lazy(_) => execute(other, &self.cache),
            },
        };
        self.stats.finish(req.op_name(), &outcome);
        outcome
    }

    fn monitor_open(
        &self,
        sel: &WorkflowSel,
        tol: Option<f64>,
        bands: bool,
    ) -> Result<Response, ApiError> {
        let mut slot = self.monitor.lock().unwrap_or_else(|e| e.into_inner());
        if slot.is_some() {
            return Err(ApiError::bad_request(
                "a monitor is already open in this session \
                 (close it with monitor_status {\"close\": true})",
            ));
        }
        let mut opts = MonitorOpts::default();
        if let Some(t) = tol {
            opts.calibrate.tol = t;
        }
        opts.bands = bands;
        // the selector picks the allocation model advisories sweep; a
        // `Trace` selector instead seeds the monitor with an initial feed
        let mut seed: Option<(&str, Option<&str>)> = None;
        let (label, advisor): (&str, Option<Arc<dyn SweepModel>>) = match sel {
            WorkflowSel::Video => ("video", Some(Arc::new(VideoScenario::default()))),
            WorkflowSel::Genomics => ("genomics", Some(Arc::new(GenomicsScenario::default()))),
            WorkflowSel::Spec(text) => {
                // fixed workflows expose no split knob: advisories will be
                // shift-only; still validate the spec up front
                let wf = parse_workflow(text)
                    .map_err(|e| ApiError::new(ErrorCode::InvalidSpec, e.to_string()))?;
                ("spec", Some(Arc::new(FixedWorkflow::new("spec", wf))))
            }
            WorkflowSel::Trace { tsv, io } => {
                seed = Some((tsv.as_str(), io.as_deref()));
                ("trace", None)
            }
        };
        let mut mon = Monitor::new(label, advisor, opts);
        let feed = match seed {
            Some((tsv, io)) => Some(
                mon.feed(Some(tsv), io)
                    .map_err(|e| ApiError::new(ErrorCode::InvalidTrace, e.to_string()))?,
            ),
            None => None,
        };
        let workflow = mon.label().to_string();
        *slot = Some(mon);
        Ok(Response::Monitor(MonitorResult::Opened { workflow, feed }))
    }

    fn monitor_feed(&self, tsv: Option<&str>, io: Option<&str>) -> Result<Response, ApiError> {
        let mut slot = self.monitor.lock().unwrap_or_else(|e| e.into_inner());
        let mon = slot.as_mut().ok_or_else(no_monitor)?;
        let report = mon
            .feed(tsv, io)
            .map_err(|e| ApiError::new(ErrorCode::InvalidTrace, e.to_string()))?;
        Ok(Response::Monitor(MonitorResult::Feed(report)))
    }

    fn monitor_status(&self, close: bool) -> Result<Response, ApiError> {
        let mut slot = self.monitor.lock().unwrap_or_else(|e| e.into_inner());
        let mon = slot.as_ref().ok_or_else(no_monitor)?;
        let status = mon.status();
        if close {
            *slot = None;
        }
        Ok(Response::Monitor(MonitorResult::Status {
            status,
            closed: close,
        }))
    }

    /// Run one request as a pool job with a dedicated reply channel —
    /// concurrent sessions sharing the pool cannot interleave results.
    /// Admission-control rejections (`overloaded`) surface as the
    /// request's outcome without ever blocking.
    fn dispatch_one(&self, pool: &Coordinator, req: &Request) -> Result<Response, ApiError> {
        let (rtx, rrx) = mpsc::channel::<JobResult>();
        pool.submit_to(
            Job {
                id: 0,
                request: req.clone(),
            },
            Some(Arc::clone(&self.cache)),
            &rtx,
        )?;
        match rrx.recv() {
            Ok(r) => r.outcome,
            Err(_) => Err(ApiError::new(
                ErrorCode::Internal,
                "worker pool died before replying",
            )),
        }
    }

    /// The full wire path: decode one JSON line (v1 envelope or legacy
    /// v0), execute, and encode the response in the request's dialect.
    /// Never panics on wire input; always returns exactly one response
    /// object echoing the request id (`null` when the id was unusable).
    pub fn handle_wire(&self, line: &str) -> Json {
        let wire = decode_line(line);
        let outcome = wire.body.and_then(|req| self.handle(&req));
        encode(wire.v, wire.id, &outcome)
    }

    /// The pool `batch` fans out over: the shared server pool in session
    /// mode, else a lazily-created private pool kept for the handler's
    /// lifetime (recovering the slot's mutex if a prior caller panicked).
    fn batch_pool(&self) -> Arc<Coordinator> {
        match &self.pool {
            PoolMode::Shared(pool) => Arc::clone(pool),
            PoolMode::Lazy(slot) => {
                let mut slot = slot.lock().unwrap_or_else(|e| e.into_inner());
                Arc::clone(slot.get_or_insert_with(|| {
                    Arc::new(Coordinator::with_cache(self.threads, Arc::clone(&self.cache)))
                }))
            }
        }
    }

    fn handle_batch(&self, requests: &[Request]) -> Result<Response, ApiError> {
        if requests.is_empty() {
            return Err(ApiError::bad_request("batch needs at least one request"));
        }
        let pool = self.batch_pool();
        let (rtx, rrx) = mpsc::channel::<JobResult>();
        let mut outcomes: Vec<Option<Result<Response, ApiError>>> = vec![None; requests.len()];
        let mut pending = 0usize;
        for (i, req) in requests.iter().enumerate() {
            let job = Job {
                id: i as u64,
                request: req.clone(),
            };
            // admission is per item: a full queue rejects this item with
            // `overloaded` while already-admitted items still run
            match pool.submit_to(job, Some(Arc::clone(&self.cache)), &rtx) {
                Ok(()) => pending += 1,
                Err(e) => outcomes[i] = Some(Err(e)),
            }
        }
        drop(rtx); // workers hold the only remaining senders
        for _ in 0..pending {
            match rrx.recv() {
                Ok(r) => outcomes[r.id as usize] = Some(r.outcome),
                Err(_) => break, // pool died; surviving slots stay None
            }
        }
        Ok(Response::Batch(
            outcomes
                .into_iter()
                .map(|slot| {
                    slot.unwrap_or_else(|| {
                        Err(ApiError::new(
                            ErrorCode::Internal,
                            "worker pool dropped a batch item",
                        ))
                    })
                })
                .collect(),
        ))
    }
}

/// Execute one non-batch request against a shared analysis cache with the
/// machine's full parallelism. Pure apart from the cache (results are
/// bit-for-bit identical with or without it).
pub fn execute(req: &Request, cache: &Arc<AnalysisCache>) -> Result<Response, ApiError> {
    execute_with_threads(req, cache, num_threads())
}

/// [`execute`] with an explicit solver fan-out budget for `sweep`
/// requests. Pool workers pass `1` — the pool itself is the parallelism
/// across jobs, and K concurrent sweeps each spawning `num_threads()`
/// scoped threads would oversubscribe the machine quadratically. Results
/// are identical for any budget (the engine's determinism contract).
pub fn execute_with_threads(
    req: &Request,
    cache: &Arc<AnalysisCache>,
    sweep_threads: usize,
) -> Result<Response, ApiError> {
    match req {
        Request::Ping => Ok(Response::Pong),
        Request::Analyze { spec } => run_analyze(spec, cache),
        Request::Sweep {
            workflow,
            perturbations,
        } => run_sweep(workflow, perturbations, cache, sweep_threads),
        Request::Sensitivity { workflow, h } => {
            run_sensitivity(workflow, *h, cache, sweep_threads)
        }
        Request::Calibrate { tsv, io, tol } => run_calibrate(tsv, io.as_deref(), *tol),
        Request::Batch { .. } => Err(ApiError::bad_request("batch requests cannot nest")),
        Request::MonitorOpen { .. } | Request::MonitorFeed { .. } | Request::MonitorStatus { .. } => {
            Err(ApiError::bad_request(
                "monitor ops are session-scoped and cannot run inside a batch",
            ))
        }
        Request::Stats { .. } => Err(ApiError::bad_request(
            "stats is service-scoped and cannot run inside a batch",
        )),
    }
}

fn no_monitor() -> ApiError {
    ApiError::bad_request("no monitor open in this session (send monitor_open first)")
}

fn run_analyze(spec: &str, cache: &Arc<AnalysisCache>) -> Result<Response, ApiError> {
    let wf = parse_workflow(spec)
        .map_err(|e| ApiError::new(ErrorCode::InvalidSpec, e.to_string()))?;
    let wa = analyze_fixpoint_cached(&wf, &SolverOpts::default(), 6, Some(cache.as_ref()))
        .map_err(|e| ApiError::new(ErrorCode::AnalysisFailed, e.to_string()))?;
    let schedule = wa
        .schedule(&wf)
        .into_iter()
        .map(|(name, start, finish)| ScheduleRow {
            name,
            start,
            finish,
        })
        .collect();
    let mut bottlenecks = Vec::new();
    for (i, a) in wa.analyses.iter().enumerate() {
        let p = &wf.nodes[i].process;
        for s in &a.segments {
            bottlenecks.push(SegmentRow {
                process: p.name.clone(),
                start: s.start,
                end: s.end,
                bottleneck: a.bottleneck_name(p, s.bottleneck),
            });
        }
    }
    Ok(Response::Analyze(AnalyzeResult {
        makespan: wa.makespan,
        events: wa.events,
        passes: wa.passes,
        schedule,
        bottlenecks,
    }))
}

/// Resolve a workflow selector to the sweep model every perturbation-based
/// op (`sweep`, `sensitivity`) runs over.
fn select_model(sel: &WorkflowSel) -> Result<Arc<dyn SweepModel>, ApiError> {
    Ok(match sel {
        WorkflowSel::Video => Arc::new(VideoScenario::default()),
        WorkflowSel::Genomics => Arc::new(GenomicsScenario::default()),
        WorkflowSel::Spec(text) => {
            let wf = parse_workflow(text)
                .map_err(|e| ApiError::new(ErrorCode::InvalidSpec, e.to_string()))?;
            Arc::new(FixedWorkflow::new("spec", wf))
        }
        WorkflowSel::Trace { tsv, io } => {
            // parse → calibrate → assemble only: the replay validation a
            // `calibrate` op performs would be solved and thrown away here
            let cal = calibrated_workflow(tsv, io.as_deref(), &CalibrateOpts::default())?;
            Arc::new(FixedWorkflow::new("trace", cal.workflow))
        }
    })
}

/// A rejected perturbation kind carries the model's applicable vocabulary
/// in `detail.applicable`, so clients can self-correct.
fn unsupported_knob_error(message: String, model: &dyn SweepModel) -> ApiError {
    let applicable: Vec<Json> = Perturbation::applicable_kinds(model)
        .into_iter()
        .map(|k| Json::Str(k.to_string()))
        .collect();
    ApiError::bad_request(message)
        .with_detail(Json::obj(vec![("applicable", Json::Arr(applicable))]))
}

fn run_sweep(
    sel: &WorkflowSel,
    perturbations: &[Perturbation],
    cache: &Arc<AnalysisCache>,
    threads: usize,
) -> Result<Response, ApiError> {
    if perturbations.is_empty() {
        return Err(ApiError::bad_request("sweep needs at least one perturbation"));
    }
    let model = select_model(sel)?;
    let label = model.label().to_string();
    let engine = SweepBatch::over(Arc::clone(&model))
        .with_threads(threads)
        .with_cache(Arc::clone(cache));
    let (outcomes, report) = engine.run_report(perturbations).map_err(|e| match e {
        SweepError::Unsupported(m) => unsupported_knob_error(m, model.as_ref()),
        SweepError::Analysis(err) => ApiError::new(ErrorCode::AnalysisFailed, err.to_string()),
    })?;
    let makespans: Vec<Option<f64>> = outcomes.iter().map(|o| o.makespan).collect();
    let mut best: Option<(usize, f64)> = None;
    for (i, m) in makespans.iter().enumerate() {
        if let Some(t) = m {
            let better = match best {
                None => true,
                Some((_, bt)) => *t < bt,
            };
            if better {
                best = Some((i, *t));
            }
        }
    }
    Ok(Response::Sweep(SweepResult {
        workflow: label,
        perturbations: perturbations.to_vec(),
        makespans,
        best,
        events: report.total_events,
        ranked: report.ranked,
        cache: report.cache,
    }))
}

/// The `sensitivity` op: per-knob makespan derivatives, the calibration
/// confidence band and the ranked fix-this-first report
/// (`docs/SENSITIVITY.md`). A `Trace` selector runs the replay validator
/// so its per-task relative errors become the band's residuals; the
/// built-in and inline-spec models carry no observations, so their band
/// collapses to the point estimate.
fn run_sensitivity(
    sel: &WorkflowSel,
    h: Option<f64>,
    cache: &Arc<AnalysisCache>,
    threads: usize,
) -> Result<Response, ApiError> {
    let (model, residuals): (Arc<dyn SweepModel>, Vec<f64>) = match sel {
        WorkflowSel::Trace { tsv, io } => {
            let cal = calibrated_workflow(tsv, io.as_deref(), &CalibrateOpts::default())?;
            let rep = replay(&cal, &SolverOpts::default())
                .map_err(|e| ApiError::new(ErrorCode::AnalysisFailed, e.to_string()))?;
            let residuals = rep
                .per_task
                .iter()
                .map(|t| t.rel_err.unwrap_or(0.0))
                .collect();
            let model: Arc<dyn SweepModel> = Arc::new(FixedWorkflow::new("trace", cal.workflow));
            (model, residuals)
        }
        other => (select_model(other)?, vec![]),
    };
    let mut opts = SenseOpts {
        threads,
        cache: Some(Arc::clone(cache)),
        ..SenseOpts::default()
    };
    if let Some(h) = h {
        opts.h = h;
    }
    let report = crate::sense::analyze(&model, &residuals, &opts).map_err(|e| match e {
        SweepError::Unsupported(m) => ApiError::bad_request(m),
        SweepError::Analysis(err) => ApiError::new(ErrorCode::AnalysisFailed, err.to_string()),
    })?;
    Ok(Response::Sensitivity(report))
}

/// The trace pipeline up to a solver-ready model (parse → calibrate →
/// assemble, **no replay**): every failure here is the input's fault, so
/// the code is `invalid_trace`.
fn calibrated_workflow(
    tsv: &str,
    io: Option<&str>,
    opts: &CalibrateOpts,
) -> Result<CalibratedWorkflow, ApiError> {
    let build = || -> crate::util::Result<CalibratedWorkflow> {
        let trace = parse_tsv(tsv)?;
        let series = match io {
            Some(text) => parse_io_log(text)?,
            None => vec![],
        };
        assemble(calibrate(&trace, &series, opts)?)
    };
    build().map_err(|e| ApiError::new(ErrorCode::InvalidTrace, e.to_string()))
}

fn run_calibrate(tsv: &str, io: Option<&str>, tol: Option<f64>) -> Result<Response, ApiError> {
    let mut opts = CalibrateOpts::default();
    if let Some(t) = tol {
        opts.tol = t;
    }
    let cal = calibrated_workflow(tsv, io, &opts)?;
    // the replay is an *analysis* of a well-formed model — its failures
    // (e.g. a task that never finishes) are `analysis_failed`, per the
    // documented taxonomy
    let report = replay(&cal, &SolverOpts::default())
        .map_err(|e| ApiError::new(ErrorCode::AnalysisFailed, e.to_string()))?;
    Ok(Response::Calibrate(CalibrateResult {
        tasks: cal.task_summaries(&report),
        predicted_makespan: report.predicted_makespan,
        observed_makespan: report.observed_makespan,
        max_rel_err: report.max_rel_err,
        events: report.events,
        passes: report.passes,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::test_fixtures::TINY_SPEC;

    #[test]
    fn analyze_through_the_handler() {
        let h = ApiHandler::new();
        let r = h
            .handle(&Request::Analyze {
                spec: TINY_SPEC.to_string(),
            })
            .unwrap();
        match r {
            Response::Analyze(a) => {
                assert!((a.makespan.unwrap() - 5.0).abs() < 1e-6);
                assert_eq!(a.schedule.len(), 1);
                assert!(!a.bottlenecks.is_empty());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bad_spec_is_invalid_spec() {
        let h = ApiHandler::new();
        let e = h
            .handle(&Request::Analyze { spec: "{}".into() })
            .unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidSpec);
    }

    /// The acceptance scenario: a generic sweep over the genomics workflow
    /// with a non-fraction (pool-capacity) knob returns a ranked report
    /// with cache stats.
    #[test]
    fn generic_genomics_sweep_with_pool_knob() {
        let h = ApiHandler::new();
        let r = h
            .handle(&Request::Sweep {
                workflow: WorkflowSel::Genomics,
                perturbations: vec![
                    Perturbation::LinkRateScale(2.0),
                    Perturbation::Identity,
                ],
            })
            .unwrap();
        match r {
            Response::Sweep(s) => {
                assert_eq!(s.workflow, "genomics");
                assert_eq!(s.makespans.len(), 2);
                assert!(s.makespans.iter().all(|m| m.is_some()));
                assert!(!s.ranked.is_empty());
                assert!(s.cache.is_some());
                assert!(s.best.is_some());
            }
            other => panic!("{other:?}"),
        }
    }

    /// A rejected knob names the model's applicable vocabulary in
    /// `detail.applicable` — the genomics list here, the full list for
    /// video (the satellite contract).
    #[test]
    fn unsupported_knob_maps_to_bad_request() {
        let h = ApiHandler::new();
        let e = h
            .handle(&Request::Sweep {
                workflow: WorkflowSel::Genomics,
                perturbations: vec![Perturbation::Task3TimeScale(2.0)],
            })
            .unwrap_err();
        assert_eq!(e.code, ErrorCode::BadRequest);
        assert!(e.message.contains("task3_time_scale"), "{}", e.message);
        let applicable = e.detail.unwrap();
        let kinds: Vec<&str> = applicable
            .get("applicable")
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(|k| k.as_str())
            .collect();
        assert_eq!(
            kinds,
            vec!["identity", "fraction", "link_rate_scale", "input_scale", "cpu_scale"]
        );
    }

    #[test]
    fn sweep_over_inline_spec_identity() {
        let h = ApiHandler::new();
        let r = h
            .handle(&Request::Sweep {
                workflow: WorkflowSel::Spec(TINY_SPEC.to_string()),
                perturbations: vec![Perturbation::Identity],
            })
            .unwrap();
        match r {
            Response::Sweep(s) => {
                assert_eq!(s.workflow, "spec");
                assert!((s.makespans[0].unwrap() - 5.0).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    /// Batch runs heterogeneous requests through the pool and reports
    /// per-item outcomes in submission order.
    #[test]
    fn batch_heterogeneous_through_pool() {
        let h = ApiHandler::with_threads(3);
        let r = h
            .handle(&Request::Batch {
                requests: vec![
                    Request::Ping,
                    Request::Analyze {
                        spec: TINY_SPEC.to_string(),
                    },
                    Request::Analyze { spec: "{}".into() },
                ],
            })
            .unwrap();
        match r {
            Response::Batch(items) => {
                assert_eq!(items.len(), 3);
                assert!(matches!(items[0], Ok(Response::Pong)));
                match &items[1] {
                    Ok(Response::Analyze(a)) => {
                        assert!((a.makespan.unwrap() - 5.0).abs() < 1e-6)
                    }
                    other => panic!("{other:?}"),
                }
                assert_eq!(items[2].as_ref().unwrap_err().code, ErrorCode::InvalidSpec);
            }
            other => panic!("{other:?}"),
        }
    }

    const MONITOR_TSV: &str = "task_id\tdeps\tstart\tcomplete\trealtime\tpcpu\trchar\twchar\tpeak_rss\n\
        dl\t-\t0\t10\t10\t1e9\t1e8\t1e8\t2e6\n\
        enc\tdl\t0\t20\t20\t100\t1e8\t5e7\t8e6\n";

    /// The full monitor lifecycle through the typed handler: open, feed,
    /// status, close, and the errors on either side of the lifecycle.
    #[test]
    fn monitor_lifecycle_through_the_handler() {
        let h = ApiHandler::new();
        // feed before open
        let e = h
            .handle(&Request::MonitorFeed {
                tsv: Some(MONITOR_TSV.to_string()),
                io: None,
            })
            .unwrap_err();
        assert!(e.message.contains("monitor_open"), "{}", e.message);

        let r = h
            .handle(&Request::MonitorOpen {
                workflow: WorkflowSel::Video,
                tol: None,
                bands: false,
            })
            .unwrap();
        assert!(matches!(
            r,
            Response::Monitor(MonitorResult::Opened { feed: None, .. })
        ));
        // double open
        let e = h
            .handle(&Request::MonitorOpen {
                workflow: WorkflowSel::Video,
                tol: None,
                bands: false,
            })
            .unwrap_err();
        assert!(e.message.contains("already open"), "{}", e.message);

        let r = h
            .handle(&Request::MonitorFeed {
                tsv: Some(MONITOR_TSV.to_string()),
                io: None,
            })
            .unwrap();
        match r {
            Response::Monitor(MonitorResult::Feed(f)) => {
                assert!(f.stale.is_none(), "{f:?}");
                let snap = f.snapshot.unwrap();
                assert_eq!(snap.tasks, 2);
                assert!(snap.makespan.is_some());
            }
            other => panic!("{other:?}"),
        }
        // malformed events are invalid_trace, and the session survives
        let e = h
            .handle(&Request::MonitorFeed {
                tsv: None,
                io: Some("dl not-a-number 0 0\n".to_string()),
            })
            .unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidTrace);

        let r = h
            .handle(&Request::MonitorStatus { close: true })
            .unwrap();
        match r {
            Response::Monitor(MonitorResult::Status { status, closed }) => {
                assert!(closed);
                assert_eq!(status.events, 1);
                assert_eq!(status.tasks, 2);
            }
            other => panic!("{other:?}"),
        }
        // closed: feeds fail again, and a fresh open works
        assert!(h.handle(&Request::MonitorStatus { close: false }).is_err());
        assert!(h
            .handle(&Request::MonitorOpen {
                workflow: WorkflowSel::Genomics,
                tol: None,
                bands: false,
            })
            .is_ok());
    }

    /// A `Trace` selector seeds the monitor with the trace as its first
    /// event, so `open` already returns a prediction.
    #[test]
    fn monitor_open_with_trace_seeds_a_feed() {
        let h = ApiHandler::new();
        let r = h
            .handle(&Request::MonitorOpen {
                workflow: WorkflowSel::Trace {
                    tsv: MONITOR_TSV.to_string(),
                    io: None,
                },
                tol: None,
                bands: true,
            })
            .unwrap();
        match r {
            Response::Monitor(MonitorResult::Opened { workflow, feed }) => {
                assert_eq!(workflow, "trace");
                let f = feed.unwrap();
                assert_eq!(f.refit, 2);
                let snap = f.snapshot.unwrap();
                assert!(snap.makespan.is_some());
                // opened with bands: the seeded feed already carries one
                let band = snap.band.expect("bands requested at open");
                assert!(band.lower <= band.median && band.median <= band.upper);
            }
            other => panic!("{other:?}"),
        }
    }

    /// Monitor ops inside a batch hit the stateless-pool guard.
    #[test]
    fn monitor_ops_cannot_ride_in_a_batch() {
        let h = ApiHandler::with_threads(2);
        let r = h
            .handle(&Request::Batch {
                requests: vec![Request::MonitorStatus { close: false }],
            })
            .unwrap();
        match r {
            Response::Batch(items) => {
                let e = items[0].as_ref().unwrap_err();
                assert!(e.message.contains("session-scoped"), "{}", e.message);
            }
            other => panic!("{other:?}"),
        }
    }

    /// The acceptance scenario: `sensitivity` returns a ranked per-knob
    /// report for all four selector families. Built-ins and inline specs
    /// have no observations, so their band is the point estimate; a
    /// trace-calibrated model gets residual-driven bands.
    #[test]
    fn sensitivity_over_every_selector_family() {
        let h = ApiHandler::new();
        for (sel, label, knob_count_at_least) in [
            (WorkflowSel::Video, "video", 8usize),
            (WorkflowSel::Genomics, "genomics", 4),
            (WorkflowSel::Spec(TINY_SPEC.to_string()), "spec", 1),
            (
                WorkflowSel::Trace {
                    tsv: MONITOR_TSV.to_string(),
                    io: None,
                },
                "trace",
                1,
            ),
        ] {
            let is_trace = matches!(sel, WorkflowSel::Trace { .. });
            let r = h
                .handle(&Request::Sensitivity {
                    workflow: sel,
                    h: None,
                })
                .unwrap();
            let report = match r {
                Response::Sensitivity(rep) => rep,
                other => panic!("{other:?}"),
            };
            assert_eq!(report.workflow, label);
            assert!(report.makespan > 0.0, "{label}: {}", report.makespan);
            assert!(
                report.knobs.len() >= knob_count_at_least,
                "{label}: {:?}",
                report.knobs.iter().map(|k| k.kind).collect::<Vec<_>>()
            );
            assert!(
                report
                    .knobs
                    .windows(2)
                    .all(|w| w[0].gain_per_unit >= w[1].gain_per_unit),
                "{label}: report must rank by gain"
            );
            assert!(
                report.band.lower <= report.band.median
                    && report.band.median <= report.band.upper,
                "{label}: {:?}",
                report.band
            );
            if !is_trace {
                assert!(report.band.is_point(), "{label}: {:?}", report.band);
            }
            assert!(report.cache.is_some(), "{label}");
        }
    }

    #[test]
    fn sensitivity_rejects_bad_specs() {
        let h = ApiHandler::new();
        let e = h
            .handle(&Request::Sensitivity {
                workflow: WorkflowSel::Spec("{}".to_string()),
                h: None,
            })
            .unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidSpec);
    }

    /// `stats` aggregates per-op counters across the handler's lifetime;
    /// `mask: true` zeroes everything time-varying for reproducible bytes.
    #[test]
    fn stats_counts_requests_and_masks() {
        let h = ApiHandler::new();
        h.handle(&Request::Ping).unwrap();
        h.handle(&Request::Ping).unwrap();
        let _ = h.handle(&Request::Analyze { spec: "{}".into() }); // errors count too
        let r = h.handle(&Request::Stats { mask: false }).unwrap();
        let s = match r {
            Response::Stats(s) => s,
            other => panic!("{other:?}"),
        };
        assert_eq!(s.ops.get("ping"), Some(&2));
        assert_eq!(s.ops.get("analyze"), Some(&1));
        assert_eq!(s.ops.get("stats"), None, "stats does not count itself");
        assert_eq!(s.inflight, 0, "nothing in flight between requests");
        assert_eq!(s.overloaded, 0);
        assert!(s.uptime_secs >= 0.0);

        let r = h.handle(&Request::Stats { mask: true }).unwrap();
        match r {
            Response::Stats(s) => assert_eq!(s, StatsSnapshot::default()),
            other => panic!("{other:?}"),
        }
        // service-scoped: cannot ride in a batch
        let r = h
            .handle(&Request::Batch {
                requests: vec![Request::Stats { mask: true }],
            })
            .unwrap();
        match r {
            Response::Batch(items) => {
                let e = items[0].as_ref().unwrap_err();
                assert!(e.message.contains("service-scoped"), "{}", e.message);
            }
            other => panic!("{other:?}"),
        }
    }

    /// The handler's cache is session-lifetime: a repeated sweep re-solves
    /// nothing.
    #[test]
    fn session_cache_spans_requests() {
        let h = ApiHandler::new();
        let req = Request::Sweep {
            workflow: WorkflowSel::Video,
            perturbations: vec![
                Perturbation::Fraction(0.5),
                Perturbation::Fraction(0.9),
            ],
        };
        let first = match h.handle(&req).unwrap() {
            Response::Sweep(s) => s,
            other => panic!("{other:?}"),
        };
        let second = match h.handle(&req).unwrap() {
            Response::Sweep(s) => s,
            other => panic!("{other:?}"),
        };
        assert_eq!(first.makespans, second.makespans);
        assert!(first.cache.unwrap().misses > 0);
        let c2 = second.cache.unwrap();
        assert_eq!(c2.misses, 0, "{c2}");
        assert!(c2.hits > 0);
    }
}
