//! The structured error taxonomy of the wire API.
//!
//! Every failure that crosses the API boundary — malformed JSON, a bad
//! field, an unsupported knob, a solve that blew up — is an [`ApiError`]:
//! a machine-readable [`ErrorCode`], a human-readable message, and an
//! optional structured `detail` payload (e.g. the index of the offending
//! batch item). The full code table with examples lives in
//! `docs/SERVICE.md`.

use std::fmt;

use crate::util::Json;

/// Machine-readable error classes. Stable wire strings (`as_str`) — new
/// codes may be added, existing ones never change meaning.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// Malformed JSON, a missing/wrong-typed field, an unknown
    /// perturbation kind, or a knob the selected workflow does not expose.
    BadRequest,
    /// The `op` is not one the protocol defines.
    UnknownOp,
    /// The `v` envelope field names a protocol this server does not speak.
    UnsupportedVersion,
    /// The workflow spec parsed as JSON but is not a valid model.
    InvalidSpec,
    /// The trace (TSV / I/O log) failed strict parsing, calibration or
    /// assembly.
    InvalidTrace,
    /// The model was well-formed but the analysis failed (e.g. a barrier
    /// dependency that never finishes).
    AnalysisFailed,
    /// The server's bounded submission queue is full (admission control).
    /// The request was *not* executed; retry after a backoff. Unlike
    /// `internal` this is an expected, load-dependent outcome.
    Overloaded,
    /// A server-side invariant broke. Never expected; file a bug.
    Internal,
}

impl ErrorCode {
    /// The wire string (`"bad_request"`, `"unknown_op"`, ...).
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownOp => "unknown_op",
            ErrorCode::UnsupportedVersion => "unsupported_version",
            ErrorCode::InvalidSpec => "invalid_spec",
            ErrorCode::InvalidTrace => "invalid_trace",
            ErrorCode::AnalysisFailed => "analysis_failed",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::Internal => "internal",
        }
    }
}

impl fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A structured API error: code + message + optional detail.
#[derive(Clone, Debug)]
pub struct ApiError {
    pub code: ErrorCode,
    pub message: String,
    /// Optional structured context (e.g. `{"index": 2}` for the offending
    /// element of an array field).
    pub detail: Option<Json>,
}

impl ApiError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ApiError {
        ApiError {
            code,
            message: message.into(),
            detail: None,
        }
    }

    /// Shorthand for the most common class.
    pub fn bad_request(message: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::BadRequest, message)
    }

    /// Attach a structured detail payload.
    pub fn with_detail(mut self, detail: Json) -> ApiError {
        self.detail = Some(detail);
        self
    }

    /// The v1 wire object: `{"code", "detail"?, "message"}`.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("code", Json::Str(self.code.as_str().to_string())),
            ("message", Json::Str(self.message.clone())),
        ];
        if let Some(d) = &self.detail {
            fields.push(("detail", d.clone()));
        }
        Json::obj(fields)
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code.as_str(), self.message)
    }
}

impl std::error::Error for ApiError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_strings_are_stable() {
        assert_eq!(ErrorCode::BadRequest.as_str(), "bad_request");
        assert_eq!(ErrorCode::UnknownOp.as_str(), "unknown_op");
        assert_eq!(ErrorCode::UnsupportedVersion.as_str(), "unsupported_version");
        assert_eq!(ErrorCode::InvalidSpec.as_str(), "invalid_spec");
        assert_eq!(ErrorCode::InvalidTrace.as_str(), "invalid_trace");
        assert_eq!(ErrorCode::AnalysisFailed.as_str(), "analysis_failed");
        assert_eq!(ErrorCode::Overloaded.as_str(), "overloaded");
        assert_eq!(ErrorCode::Internal.as_str(), "internal");
    }

    #[test]
    fn to_json_shape() {
        let e = ApiError::bad_request("nope");
        assert_eq!(e.to_json().to_string(), r#"{"code":"bad_request","message":"nope"}"#);
        let e = e.with_detail(Json::obj(vec![("index", Json::Num(2.0))]));
        assert_eq!(
            e.to_json().to_string(),
            r#"{"code":"bad_request","detail":{"index":2},"message":"nope"}"#
        );
    }
}
