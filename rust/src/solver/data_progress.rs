//! Data progress: `P_Dk(t) = R_Dk(I_Dk(t))` and the envelope
//! `P_D(t) = min_k P_Dk(t)` (paper §3.1, eqs. 1–3).

use crate::model::process::{Process, ProcessInputs};
use crate::pwfn::{Envelope, PwPoly};

/// Compute all per-input data progress functions and their lower envelope.
///
/// A process without data inputs gets the constant envelope at
/// `max_progress` (data never limits it).
pub fn data_envelope(process: &Process, inputs: &ProcessInputs) -> (Vec<PwPoly>, Envelope) {
    let t0 = inputs.start_time;
    let data_progress: Vec<PwPoly> = process
        .data_reqs
        .iter()
        .zip(inputs.data.iter())
        .map(|(req, input)| {
            // shift/clamp the input to the process start: data available
            // before the start is simply available at the start
            let shifted = if input.x_min() > t0 {
                // not yet defined at start: clamp semantics of eval handle it,
                // but materialize the leading constant for clean breaks
                let lead = PwPoly::constant_from(t0, input.eval(input.x_min()));
                // min is wrong here; build explicit concatenation
                concat(lead.clip(t0, input.x_min()), input.clone())
            } else {
                input.clone()
            };
            // by-value clip: the common "input already starts at t0" case
            // returns the compose result itself, no copy
            req.func.compose(&shifted).clipped(t0, f64::INFINITY)
        })
        .collect();
    let env = if data_progress.is_empty() {
        Envelope {
            func: PwPoly::constant_from(t0, process.max_progress),
            winners: vec![0],
        }
    } else {
        // single k-way sweep (with a clone-light single-input fast path)
        let refs: Vec<&PwPoly> = data_progress.iter().collect();
        PwPoly::min_envelope(&refs)
    };
    (data_progress, env)
}

/// Concatenate two piecewise functions with adjacent domains
/// (`a.x_max() == b.x_min()`).
fn concat(a: PwPoly, b: PwPoly) -> PwPoly {
    let mut breaks = a.breaks.clone();
    breaks.pop();
    let mut polys = a.polys.clone();
    breaks.extend_from_slice(&b.breaks);
    polys.extend_from_slice(&b.polys);
    PwPoly::new(breaks, polys)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builder::ProcessBuilder;

    #[test]
    fn envelope_of_two_inputs() {
        // paper Fig 3 style: one linear input, one burst input
        let proc = ProcessBuilder::new("t", 100.0)
            .stream_data("a", 100.0)
            .burst_data("b", 50.0)
            .build();
        let inputs = ProcessInputs {
            data: vec![
                PwPoly::ramp_to(0.0, 10.0, 100.0), // done at t=10
                PwPoly::ramp_to(0.0, 10.0, 50.0),  // done at t=5 -> jump
            ],
            resources: vec![],
            start_time: 0.0,
        };
        let (dps, env) = data_envelope(&proc, &inputs);
        assert_eq!(dps.len(), 2);
        // before t=5: burst input gives 0 -> envelope 0, winner b (=1)
        assert_eq!(env.func.eval(4.0), 0.0);
        assert_eq!(env.winner_at(4.0), 1);
        // after t=5: burst jumps to 100, linear gives 10t
        assert!((env.func.eval(6.0) - 60.0).abs() < 1e-9);
        assert_eq!(env.winner_at(6.0), 0);
    }

    #[test]
    fn no_data_inputs_unlimited() {
        let proc = ProcessBuilder::new("t", 42.0).build();
        let inputs = ProcessInputs {
            data: vec![],
            resources: vec![],
            start_time: 1.0,
        };
        let (_, env) = data_envelope(&proc, &inputs);
        assert_eq!(env.func.eval(1.0), 42.0);
        assert_eq!(env.func.eval(100.0), 42.0);
    }

    #[test]
    fn input_defined_after_start_clamped() {
        // input function starts at t=5 (e.g. predecessor output shifted)
        let proc = ProcessBuilder::new("t", 10.0).stream_data("a", 10.0).build();
        let inputs = ProcessInputs {
            data: vec![PwPoly::ramp_to(5.0, 1.0, 10.0)],
            resources: vec![],
            start_time: 0.0,
        };
        let (dps, _) = data_envelope(&proc, &inputs);
        assert_eq!(dps[0].eval(0.0), 0.0);
        assert_eq!(dps[0].eval(5.0), 0.0);
        assert!((dps[0].eval(10.0) - 5.0).abs() < 1e-9);
    }
}
