//! Analysis results: the progress function, the piecewise bottleneck
//! function, and the §3.3 derived metrics (resource usage, buffered data).

use crate::model::process::{Process, ProcessInputs};
use crate::pwfn::{Envelope, PwPoly};

/// What limits progress on a time interval (the paper's piecewise-defined
/// bottleneck function, derived from the discrete intersections of the
/// task model's limiting functions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bottleneck {
    /// Limited by data input `k` (index into `Process::data_reqs`).
    Data(usize),
    /// Limited by resource `l` (index into `Process::res_reqs`).
    Resource(usize),
    /// Not limited (a process with no data inputs running at allocation-
    /// unconstrained speed, or an instantaneous jump).
    None,
}

/// A maximal time interval with a constant limiting factor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Segment {
    pub start: f64,
    pub end: f64,
    pub bottleneck: Bottleneck,
}

/// The full result of analyzing one process execution.
///
/// `PartialEq` compares every field (progress pieces, segments, events) —
/// the sweep engine's bit-for-bit determinism checks rely on it.
#[derive(Clone, Debug, PartialEq)]
pub struct Analysis {
    /// The progress function `P(t)`, constant at `max_progress` after
    /// completion (domain `[start_time, inf)`).
    pub progress: PwPoly,
    /// Per-input data progress functions `P_Dk(t) = R_Dk(I_Dk(t))`.
    pub data_progress: Vec<PwPoly>,
    /// `P_D(t) = min_k P_Dk(t)` with winner attribution.
    pub pd: Envelope,
    /// Bottleneck segmentation of `[start_time, finish]`.
    pub segments: Vec<Segment>,
    /// Wall-clock completion time (`None` if the process never finishes
    /// within the solver horizon).
    pub finish_time: Option<f64>,
    pub start_time: f64,
    pub max_progress: f64,
    /// Number of solver events (for the §6 performance accounting: cost is
    /// proportional to piece/limit changes, *not* to bytes moved).
    pub events: usize,
}

impl Analysis {
    /// Output function over wall time, `O_m(P(t))` — directly usable as the
    /// data input function of a successor process (paper §3.4).
    pub fn output_over_time(&self, process: &Process, m: usize) -> PwPoly {
        process.outputs[m].func.compose(&self.progress)
    }

    /// Exact resource demand over time: `P'(t) · R'_Rl(P(t))` (paper eq. 4).
    ///
    /// Caveat: on stall intervals (a jump in `R_Rl` being "paid off")
    /// `P' = 0`, so this reports 0 even though the stalled resource is being
    /// consumed at its allocated rate; the evaluation models use stream-type
    /// resources where stalls do not occur.
    pub fn resource_demand(&self, process: &Process, l: usize) -> PwPoly {
        self.resource_demand_with(&self.progress.derivative(), process, l)
    }

    /// [`Analysis::resource_demand`] with the progress derivative `P'(t)`
    /// precomputed — hot callers (the cache's `NodeSolve::derive`) charge
    /// several resources from one analysis and should not rebuild the
    /// derivative per resource. `dp` must be `self.progress.derivative()`;
    /// results are bit-for-bit those of `resource_demand`.
    pub fn resource_demand_with(&self, dp: &PwPoly, process: &Process, l: usize) -> PwPoly {
        let drl = process.res_reqs[l].func.derivative();
        let cost_along_p = drl.compose(&self.progress);
        dp.mul(&cost_along_p)
    }

    /// Relative resource usage (paper eq. 7), sampled on `ts`:
    /// `P'(t)·R'(P(t)) / I_Rl(t)`, clamped to `[0, 1]`; 0 where the
    /// allocation is 0.
    pub fn relative_usage_sampled(
        &self,
        process: &Process,
        inputs: &ProcessInputs,
        l: usize,
        ts: &[f64],
    ) -> Vec<f64> {
        let demand = self.resource_demand(process, l);
        ts.iter()
            .map(|&t| {
                let alloc = inputs.resources[l].eval(t);
                if alloc <= 0.0 {
                    0.0
                } else {
                    (demand.eval(t) / alloc).clamp(0.0, 1.0)
                }
            })
            .collect()
    }

    /// Bytes of input `k` consumed by time `t`: the smallest `n` with
    /// `R_Dk(n) >= P(t)` (the `R_Dk^{-1}(P(t))` of paper eq. 8, generalized
    /// to non-invertible requirement functions by the first-reach
    /// convention).
    pub fn data_consumed_at(&self, process: &Process, k: usize, t: f64) -> f64 {
        let p = self.progress.eval(t);
        process.data_reqs[k]
            .func
            .inverse_at(p)
            .unwrap_or(0.0)
    }

    /// Buffered (provided but unused) data of input `k` (paper eq. 8),
    /// sampled on `ts`: `I_Dk(t) - R_Dk^{-1}(P(t))`.
    pub fn buffered_data_sampled(
        &self,
        process: &Process,
        inputs: &ProcessInputs,
        k: usize,
        ts: &[f64],
    ) -> Vec<f64> {
        ts.iter()
            .map(|&t| {
                (inputs.data[k].eval(t) - self.data_consumed_at(process, k, t)).max(0.0)
            })
            .collect()
    }

    /// The bottleneck governing time `t` (`None` outside all segments,
    /// e.g. after completion).
    pub fn bottleneck_at(&self, t: f64) -> Option<Bottleneck> {
        self.segments
            .iter()
            .find(|s| t >= s.start && t < s.end)
            .map(|s| s.bottleneck)
    }

    /// Human-readable name for a bottleneck of this process.
    pub fn bottleneck_name(&self, process: &Process, b: Bottleneck) -> String {
        match b {
            Bottleneck::Data(k) => format!("data:{}", process.data_reqs[k].name),
            Bottleneck::Resource(l) => format!("res:{}", process.res_reqs[l].name),
            Bottleneck::None => "unconstrained".to_string(),
        }
    }

    /// Makespan relative to process start (`None` if unfinished).
    pub fn duration(&self) -> Option<f64> {
        self.finish_time.map(|f| f - self.start_time)
    }
}
