//! Algorithm 2: the practical, event-driven exact solver (paper §4).
//!
//! With piecewise-*linear* resource requirement functions, the divisor in
//! `P'(t) <= min_l I_Rl(t) / R'_Rl(P(t))` (paper eq. 9) is piecewise-
//! constant in `p`, so on a region where every involved function stays on
//! one piece the progress function is simply the antiderivative of a
//! polynomial. The solver therefore advances from event to event — the
//! discrete points where a piece or the limiting factor changes — exactly as
//! the paper prescribes, never iterating over raw time steps. Its cost is a
//! function of model complexity only, *independent of the amount of data
//! simulated* (the §6 headline).
//!
//! Event types handled:
//! * end of the current `P_D` piece (data envelope, incl. winner changes);
//! * a jump in `P_D` (burst input becoming available);
//! * end of the current `I_Rl` piece for any resource;
//! * `P` crossing a breakpoint of any `R'_Rl` (p-region change);
//! * a jump in `R_Rl` at the current progress (stall until the cumulative
//!   allocation covers it);
//! * `P_D`'s slope starting to exceed the resource speed limit
//!   (data-limited → resource-limited);
//! * the resource-limited `P` catching up with `P_D`
//!   (resource-limited → data-limited);
//! * the speed-limit envelope switching between resources;
//! * `P` reaching `max_progress` (completion).
//!
//! # Invariants
//!
//! * **Purity & determinism**: [`solve`] reads nothing but its three
//!   arguments and allocates no global state; identical inputs produce a
//!   bit-for-bit identical [`Analysis`], including the event count. The
//!   sweep engine's determinism contract and the analysis cache
//!   ([`crate::runtime::cache`]) both rest on this — do not add wall-clock,
//!   RNG or thread-dependent behavior here.
//! * Requirement functions are monotone nondecreasing and resource
//!   requirements piecewise-linear (checked by `Process::validate`), so the
//!   speed divisor `R'_Rl(p)` is piecewise-constant in `p`.
//! * The returned progress function is nondecreasing, right-continuous,
//!   constant at `max_progress` after `finish_time`, and its bottleneck
//!   segments tile `[start_time, finish]`.
//!
//! # Cost model
//!
//! Each loop iteration emits ≥ 1 solver event and advances `(t, p)` past at
//! least one breakpoint, envelope crossing, stall payoff or completion, so
//! the loop count is `O(pieces × limit changes)` — a function of **model
//! complexity only**, independent of the simulated data volume (the §6
//! headline; `benches/sec6_scaling.rs` measures it). Per event the work is
//! small-degree polynomial root finding over the current pieces, i.e.
//! `O(resources + data inputs)` with tiny constants. `SolverOpts::max_events`
//! caps pathological cases.

use crate::model::process::{ModelError, Process, ProcessInputs};
use crate::pwfn::piecewise::poly_continues;
use crate::pwfn::{poly::Poly, PwPoly};

use super::analysis::{Analysis, Bottleneck, Segment};
use super::data_progress::data_envelope;

/// Solver configuration.
#[derive(Clone, Debug)]
pub struct SolverOpts {
    /// Give up (finish_time = None) past this wall-clock time.
    pub horizon: f64,
    /// Hard cap on solver events (guards against numerically-stalled loops).
    pub max_events: usize,
    /// Relative progress tolerance for "reached" comparisons.
    pub tol: f64,
    /// Opt-in piece budget for materialized workflow input/demand
    /// functions (`0` = off, the default). When a function the engine
    /// materializes exceeds this many pieces it is lossily coarsened via
    /// [`crate::pwfn::PwPoly::simplify_budget`]; the worst reported error
    /// bound surfaces as `WorkflowAnalysis::budget_err`. Keeps per-node
    /// function sizes bounded on deep generated DAGs (docs/SCALING.md).
    pub piece_budget: usize,
    /// Error threshold seeding the budgeted coarsening (merges cheaper
    /// than this are taken first; the budget itself is a hard cap).
    pub piece_budget_err: f64,
}

impl Default for SolverOpts {
    fn default() -> Self {
        SolverOpts {
            horizon: 1e9,
            max_events: 200_000,
            tol: 1e-9,
            piece_budget: 0,
            piece_budget_err: 0.0,
        }
    }
}

/// Solver failure.
#[derive(Debug, Clone)]
pub enum SolveError {
    Model(ModelError),
    Stalled { t: f64, p: f64 },
    TooManyEvents(usize),
}

impl std::fmt::Display for SolveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolveError::Model(e) => e.fmt(f),
            SolveError::Stalled { t, p } => {
                write!(f, "solver made no progress at t={t}, p={p} (numerical stall)")
            }
            SolveError::TooManyEvents(n) => write!(f, "exceeded {n} events"),
        }
    }
}

impl std::error::Error for SolveError {}

impl From<ModelError> for SolveError {
    fn from(e: ModelError) -> Self {
        SolveError::Model(e)
    }
}

/// Piece-by-piece constructor for `P(t)` plus its bottleneck segmentation.
struct ProgressBuilder {
    breaks: Vec<f64>,
    polys: Vec<Poly>,
    segments: Vec<Segment>,
    tiny: f64,
}

impl ProgressBuilder {
    fn new(t0: f64) -> Self {
        ProgressBuilder {
            breaks: vec![t0],
            polys: vec![],
            segments: vec![],
            tiny: 1e-12,
        }
    }

    /// Append a piece on `[start, end)` (local coords at `start`).
    fn push(&mut self, start: f64, end: f64, poly: Poly, label: Bottleneck) {
        debug_assert!((start - *self.breaks.last().unwrap()).abs() < 1e-6 * (1.0 + start.abs()));
        if end - start < self.tiny * (1.0 + start.abs()) {
            return; // zero-width: skip (value continuity is the caller's p)
        }
        // merge with previous piece when same label and same polynomial
        // continuation (the kernel's shared EPS_BREAK criterion)
        let mergeable = if let (Some(last_poly), Some(last_seg)) =
            (self.polys.last(), self.segments.last())
        {
            let prev_start = self.breaks[self.breaks.len() - 2];
            last_seg.bottleneck == label && poly_continues(last_poly, prev_start, start, &poly)
        } else {
            false
        };
        if mergeable {
            *self.breaks.last_mut().unwrap() = end;
            self.segments.last_mut().unwrap().end = end;
        } else {
            self.polys.push(poly);
            self.breaks.push(end);
            // extend previous segment or start a new one
            if let Some(seg) = self.segments.last_mut() {
                if seg.bottleneck == label && (seg.end - start).abs() < 1e-9 * (1.0 + start.abs())
                {
                    seg.end = end;
                } else {
                    self.segments.push(Segment {
                        start,
                        end,
                        bottleneck: label,
                    });
                }
            } else {
                self.segments.push(Segment {
                    start,
                    end,
                    bottleneck: label,
                });
            }
        }
    }

    /// Close with a constant tail at `p_final` from `t` on.
    fn finish(mut self, t: f64, p_final: f64) -> (PwPoly, Vec<Segment>) {
        let last = *self.breaks.last().unwrap();
        if (t - last).abs() > 1e-9 * (1.0 + t.abs()) && t > last {
            // shouldn't happen, but keep the function well-formed
            self.polys.push(Poly::constant(p_final));
            self.breaks.push(t);
        }
        self.polys.push(Poly::constant(p_final));
        self.breaks.push(f64::INFINITY);
        if self.polys.len() == 1 {
            // degenerate: instantly-complete process
            return (
                PwPoly::new(self.breaks, self.polys),
                self.segments,
            );
        }
        (PwPoly::new(self.breaks, self.polys), self.segments)
    }
}

/// First breakpoint of `f` strictly greater than `t` (`inf` if none).
/// Binary search — this runs several times per solver event, and pd / the
/// allocation functions can carry hundreds of breaks.
fn next_break_after(f: &PwPoly, t: f64) -> f64 {
    let thr = t + 1e-12 * (1.0 + t.abs());
    let i = f.breaks.partition_point(|&b| b <= thr);
    f.breaks.get(i).copied().unwrap_or(f64::INFINITY)
}

/// Reusable per-solve scratch buffers: the event loop runs one iteration
/// per solver event, and every iteration used to allocate fresh
/// cost/limiting/speed vectors (plus an allocation-integral `PwPoly` per
/// stall check). One `SolveScratch` owned by [`solve`] amortizes all of it
/// across the whole run; the buffers never escape, so results are
/// bit-for-bit those of the allocating version.
struct SolveScratch {
    /// `R'_Rl(p)` per resource, for the current p-region.
    costs: Vec<f64>,
    /// Resources with nonzero cost in the current p-region.
    limiting: Vec<usize>,
    /// `(l, I_Rl local poly / cost_l)` speed candidates of one
    /// resource-limited step.
    speeds: Vec<(usize, Poly)>,
    /// Lazily built antiderivatives of the resource allocations (stall
    /// checks); the inputs are immutable for the whole solve, so each is
    /// built at most once.
    res_accum: Vec<Option<PwPoly>>,
}

impl SolveScratch {
    fn new(l_count: usize) -> Self {
        SolveScratch {
            costs: Vec::with_capacity(l_count),
            limiting: Vec::with_capacity(l_count),
            speeds: Vec::with_capacity(l_count),
            res_accum: vec![None; l_count],
        }
    }
}

/// Analyze one process under the given inputs (Algorithm 2).
pub fn solve(
    process: &Process,
    inputs: &ProcessInputs,
    opts: &SolverOpts,
) -> Result<Analysis, SolveError> {
    process.validate()?;
    process.validate_inputs(inputs)?;
    let t0 = inputs.start_time;

    // ---- data side: P_Dk and the envelope P_D -------------------------
    let (data_progress, pd) = data_envelope(process, inputs);

    // resource derivative functions R'_Rl(p) (piecewise-constant in p)
    let dres: Vec<PwPoly> = process
        .res_reqs
        .iter()
        .map(|r| r.func.derivative())
        .collect();
    let l_count = dres.len();

    let tolp = opts.tol * (1.0 + process.max_progress.abs());
    let mut t = t0;
    let mut p = 0.0f64.min(process.max_progress);
    let mut builder = ProgressBuilder::new(t0);
    let mut scratch = SolveScratch::new(l_count);
    let mut events = 0usize;
    let mut finished = false;

    // a process with nothing to do is instantly complete
    if process.max_progress <= tolp {
        finished = true;
    }

    while !finished {
        events += 1;
        if events > opts.max_events {
            return Err(SolveError::TooManyEvents(opts.max_events));
        }
        if t >= opts.horizon {
            break;
        }

        // ---- stall: a jump in some R_Rl at the current progress --------
        let mut stall_until = t;
        let mut stall_res = 0usize;
        for (l, r) in process.res_reqs.iter().enumerate() {
            // find a break of R_Rl at (approximately) p with an upward jump
            let jump_break = r
                .func
                .breaks
                .iter()
                .copied()
                .find(|&b| b.is_finite() && (b - p).abs() <= tolp && r.func.jump_at(b) > tolp);
            if let Some(b) = jump_break {
                let need = r.func.jump_at(b);
                // accumulate allocation: A(t') - A(t) >= need (the
                // antiderivative is built once per resource per solve)
                let acc = scratch.res_accum[l]
                    .get_or_insert_with(|| inputs.resources[l].antiderivative(0.0));
                let target = acc.eval(t) + need;
                match acc.first_reach(target, t) {
                    Some(tl) if tl < opts.horizon => {
                        if tl > stall_until {
                            stall_until = tl;
                            stall_res = l;
                        }
                    }
                    _ => {
                        // never paid: stalled forever
                        let (progress, segments) = builder.finish(t, p);
                        return Ok(Analysis {
                            progress,
                            data_progress,
                            pd,
                            segments,
                            finish_time: None,
                            start_time: t0,
                            max_progress: process.max_progress,
                            events,
                        });
                    }
                }
            }
        }
        if stall_until > t + 1e-12 * (1.0 + t.abs()) {
            builder.push(
                t,
                stall_until,
                Poly::constant(p),
                Bottleneck::Resource(stall_res),
            );
            t = stall_until;
            // nudge p past the jump break so it isn't detected again
            p += 2.0 * tolp;
            continue;
        }

        let pd_now = pd.func.eval(t);
        let gap = pd_now - p;

        // ---- current p-region: cost per progress for each resource -----
        scratch.costs.clear();
        for d in &dres {
            scratch.costs.push(d.eval(p + 2.0 * tolp));
        }
        let next_p_break = dres
            .iter()
            .map(|d| next_break_after(d, p + 2.0 * tolp))
            .fold(f64::INFINITY, f64::min)
            .min(process.max_progress);

        // window: no involved function changes piece inside it
        let mut window = next_break_after(&pd.func, t).min(opts.horizon);
        for ir in &inputs.resources {
            window = window.min(next_break_after(ir, t));
        }
        debug_assert!(window > t);

        scratch.limiting.clear();
        for l in 0..l_count {
            if scratch.costs[l] > 1e-15 {
                scratch.limiting.push(l);
            }
        }

        if gap <= tolp {
            // =============== potentially data-limited ===================
            p = pd_now; // snap
            if p >= process.max_progress - tolp {
                finished = true;
                break;
            }
            let f = pd.func.local_poly_at(t); // local at t
            let df = f.derivative();
            // while following pd, p-break crossing is also an event
            let mut w = window;
            if next_p_break.is_finite() && next_p_break > p + tolp {
                if let Some(tp) = pd.func.first_reach(next_p_break, t) {
                    if tp > t {
                        w = w.min(tp);
                    }
                }
            }
            // completion while following pd
            if let Some(tfin) = pd.func.first_reach(process.max_progress, t) {
                if tfin > t {
                    w = w.min(tfin);
                } else {
                    finished = true;
                    break;
                }
            }
            // check resource-speed violation: c_l * pd'(t) - I_Rl(t) > 0
            let mut violated_now = false;
            let mut t_viol = f64::INFINITY;
            for &l in &scratch.limiting {
                let g = df
                    .scale(scratch.costs[l])
                    .sub(&inputs.resources[l].local_poly_at(t));
                let gscale = g.coeffs.iter().fold(1e-12f64, |m, c| m.max(c.abs()));
                if g.eval(1e-9) > 1e-9 * gscale {
                    violated_now = true;
                    break;
                }
                let hi = if w.is_finite() { w - t } else { 1e12 };
                for r in g.roots_in(0.0, hi) {
                    // violation begins where g crosses upward
                    if g.eval(r + 1e-9 * (1.0 + r)) > 0.0 {
                        t_viol = t_viol.min(t + r);
                        break;
                    }
                }
            }
            if violated_now {
                // resource-limited from here on: fall through to the
                // resource branch on the next iteration
                handle_resource_limited(
                    &mut t, &mut p, &mut finished, process, inputs, &pd, &mut scratch,
                    next_p_break, window, opts, &mut builder, tolp,
                )?;
                continue;
            }
            let event = w.min(t_viol);
            if !event.is_finite() {
                // nothing ever changes again and pd is flat below max:
                // unfinished
                break;
            }
            let k = pd.winner_at(0.5 * (t + event.min(t + 1e9)));
            let label = if process.data_reqs.is_empty() {
                Bottleneck::None
            } else {
                Bottleneck::Data(k)
            };
            builder.push(t, event, f, label);
            p = pd.func.eval_left(event);
            t = event;
            if p >= process.max_progress - tolp {
                finished = true;
            }
            // (a jump of pd at `event` shows up as gap > 0 next iteration)
        } else {
            // ================== resource-limited =========================
            handle_resource_limited(
                &mut t, &mut p, &mut finished, process, inputs, &pd, &mut scratch,
                next_p_break, window, opts, &mut builder, tolp,
            )?;
        }

        if p >= process.max_progress - tolp {
            finished = true;
        }
    }

    let finish_time = if finished { Some(t) } else { None };
    let p_final = if finished { process.max_progress } else { p };
    let (progress, segments) = builder.finish(t, p_final);
    Ok(Analysis {
        progress,
        data_progress,
        pd,
        segments,
        finish_time,
        start_time: t0,
        max_progress: process.max_progress,
        events,
    })
}

/// One resource-limited step: integrate `P' = min_l I_Rl(t)/c_l` from
/// `(t, p)` until the first event, pushing the piece into `builder` and
/// advancing `(t, p)`. Speed candidates live in `scratch.speeds` (cleared
/// and refilled — no per-step vector or winner-poly clone).
#[allow(clippy::too_many_arguments)]
fn handle_resource_limited(
    t: &mut f64,
    p: &mut f64,
    finished: &mut bool,
    process: &Process,
    inputs: &ProcessInputs,
    pd: &crate::pwfn::Envelope,
    scratch: &mut SolveScratch,
    next_p_break: f64,
    window: f64,
    opts: &SolverOpts,
    builder: &mut ProgressBuilder,
    tolp: f64,
) -> Result<(), SolveError> {
    let pd_now = pd.func.eval(*t);

    if scratch.limiting.is_empty() {
        // no resource needed in this p-region: instantaneous progress up to
        // the next p-break / pd / completion
        let target = pd_now.min(next_p_break).min(process.max_progress);
        if target > *p + tolp {
            *p = target; // a jump in P at time t (no piece appended)
            if *p >= process.max_progress - tolp {
                *finished = true;
            }
            return Ok(());
        }
        // p == pd < breaks: stuck waiting on data with zero cost; follow pd
        // by jumping at its next increase
        let t_next = pd
            .func
            .first_reach(*p + tolp.max(1e-9 * (1.0 + *p)), *t)
            .unwrap_or(f64::INFINITY)
            .min(window);
        if !t_next.is_finite() || t_next >= opts.horizon {
            *t = opts.horizon;
            return Ok(());
        }
        let k = pd.winner_at(*t);
        let label = if process.data_reqs.is_empty() {
            Bottleneck::None
        } else {
            Bottleneck::Data(k)
        };
        if t_next > *t {
            builder.push(*t, t_next, Poly::constant(*p), label);
            *t = t_next;
        } else {
            return Err(SolveError::Stalled { t: *t, p: *p });
        }
        return Ok(());
    }

    // speed_l(t) = I_Rl(t) / c_l on [t, window); find the envelope winner at t
    // and the earliest crossing with any other resource's speed.
    scratch.speeds.clear();
    for &l in &scratch.limiting {
        scratch
            .speeds
            .push((l, inputs.resources[l].local_poly_at(*t).scale(1.0 / scratch.costs[l])));
    }
    let speeds = &scratch.speeds;
    // winner at t+ (smallest speed just right of t; tie-break lower index)
    let probe = 1e-9 * (1.0 + t.abs());
    let mut win = 0usize;
    let mut win_val = speeds[0].1.eval(probe);
    for (si, (_, s)) in speeds.iter().enumerate().skip(1) {
        let v = s.eval(probe);
        if v < win_val - 1e-12 * (1.0 + v.abs()) {
            win = si;
            win_val = v;
        }
    }
    let win_l = speeds[win].0;
    let hi_local = if window.is_finite() {
        window - *t
    } else {
        1e12
    };
    // crossing with any other speed
    let mut t_cross = f64::INFINITY;
    for (si, (_, s)) in speeds.iter().enumerate() {
        if si == win {
            continue;
        }
        let d = s.sub(&speeds[win].1);
        for r in d.roots_in(0.0, hi_local) {
            if r > probe && d.eval(r + probe) < 0.0 {
                t_cross = t_cross.min(*t + r);
                break;
            }
        }
    }

    // integrate the winning speed: P_cand(u) = p + ∫0^u speed
    let cand = speeds[win].1.antiderivative(*p);

    // events: reach next_p_break / max_progress / catch pd
    let mut event = window.min(t_cross).min(opts.horizon);
    let mut event_kind = 0u8; // 0 window/cross, 1 p-break or max, 2 catch, 3 done
    let targets = [next_p_break, process.max_progress];
    for (i, &tgt) in targets.iter().enumerate() {
        if tgt <= *p + tolp || !tgt.is_finite() {
            continue;
        }
        let d = cand.sub(&Poly::constant(tgt));
        if let Some(r) = d.first_root_after(0.0, hi_local.min(event - *t)) {
            let te = *t + r;
            if te < event {
                event = te;
                event_kind = if i == 1 { 3 } else { 1 };
            }
        }
    }
    // catch pd: root of cand - pd_local (only while cand < pd before)
    if pd_now > *p + tolp {
        let d = cand.sub(&pd.func.local_poly_at(*t));
        for r in d.roots_in(0.0, hi_local.min(event - *t)) {
            if r > probe {
                let te = *t + r;
                if te < event {
                    event = te;
                    event_kind = 2;
                }
                break;
            }
        }
    } else {
        // p == pd: we are here because pd' > maxspeed; cand falls behind pd,
        // no catch event until something changes
    }

    if event <= *t + 1e-12 * (1.0 + t.abs()) {
        return Err(SolveError::Stalled { t: *t, p: *p });
    }
    if !event.is_finite() {
        // speed never limited again and no target reachable: give up at
        // horizon
        let p_next = cand.eval(opts.horizon - *t);
        builder.push(*t, opts.horizon, cand, Bottleneck::Resource(win_l));
        *p = p_next;
        *t = opts.horizon;
        return Ok(());
    }

    let p_next = cand.eval(event - *t);
    builder.push(*t, event, cand, Bottleneck::Resource(win_l));
    *p = p_next;
    *t = event;
    if event_kind == 3 || *p >= process.max_progress - tolp {
        *p = process.max_progress;
        *finished = true;
    }
    let _ = event_kind;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builder::ProcessBuilder;
    use crate::solver::analysis::Bottleneck;
    use crate::pwfn::PwPoly;

    fn opts() -> SolverOpts {
        SolverOpts::default()
    }

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-6 * (1.0 + a.abs().max(b.abs()))
    }

    /// Stream task, data plentiful, CPU-limited: classic compute-bound run.
    #[test]
    fn cpu_bound_stream() {
        // 100 units of progress, needs 50 CPU-s total, gets 1 CPU/s,
        // all data available from the start
        let proc = ProcessBuilder::new("enc", 100.0)
            .stream_data("in", 1000.0)
            .stream_resource("cpu", 50.0)
            .build();
        let inputs = ProcessInputs {
            data: vec![PwPoly::constant(1000.0)],
            resources: vec![PwPoly::constant(1.0)],
            start_time: 0.0,
        };
        let a = solve(&proc, &inputs, &opts()).unwrap();
        assert!(close(a.finish_time.unwrap(), 50.0), "{:?}", a.finish_time);
        // halfway: 50 progress at t=25
        assert!(close(a.progress.eval(25.0), 50.0));
        assert_eq!(a.segments.len(), 1);
        assert_eq!(a.segments[0].bottleneck, Bottleneck::Resource(0));
    }

    /// Stream task, CPU plentiful, data-limited: download-style run.
    #[test]
    fn data_bound_stream() {
        // data trickles in at 10 B/s, needs 1000 B for 100 progress;
        // CPU is ample (needs 1 CPU-s total, gets 1/s)
        let proc = ProcessBuilder::new("rot", 100.0)
            .stream_data("in", 1000.0)
            .stream_resource("cpu", 1.0)
            .build();
        let inputs = ProcessInputs {
            data: vec![PwPoly::ramp_to(0.0, 10.0, 1000.0)],
            resources: vec![PwPoly::constant(1.0)],
            start_time: 0.0,
        };
        let a = solve(&proc, &inputs, &opts()).unwrap();
        assert!(close(a.finish_time.unwrap(), 100.0), "{:?}", a.finish_time);
        assert!(close(a.progress.eval(50.0), 50.0));
        assert_eq!(a.segments[0].bottleneck, Bottleneck::Data(0));
    }

    /// Burst data requirement: nothing happens until all input arrived, then
    /// CPU-limited processing.
    #[test]
    fn burst_then_cpu() {
        let proc = ProcessBuilder::new("rev", 100.0)
            .burst_data("in", 1000.0)
            .stream_resource("cpu", 50.0)
            .build();
        let inputs = ProcessInputs {
            data: vec![PwPoly::ramp_to(0.0, 100.0, 1000.0)], // full at t=10
            resources: vec![PwPoly::constant(1.0)],
            start_time: 0.0,
        };
        let a = solve(&proc, &inputs, &opts()).unwrap();
        // t=10 data complete; then 50 CPU-s at 1/s
        assert!(close(a.finish_time.unwrap(), 60.0), "{:?}", a.finish_time);
        assert!(close(a.progress.eval(9.9), 0.0));
        assert_eq!(a.bottleneck_at(5.0), Some(Bottleneck::Data(0)));
        assert_eq!(a.bottleneck_at(30.0), Some(Bottleneck::Resource(0)));
    }

    /// Two resources: the scarcer one wins the bottleneck attribution.
    #[test]
    fn two_resources_min() {
        let proc = ProcessBuilder::new("t", 100.0)
            .stream_resource("cpu", 100.0) // needs 1 cpu/progress
            .stream_resource("io", 50.0)   // needs 0.5 io/progress
            .build();
        let inputs = ProcessInputs {
            data: vec![],
            resources: vec![PwPoly::constant(2.0), PwPoly::constant(0.5)],
            start_time: 0.0,
        };
        // speeds: cpu 2/1=2, io 0.5/0.5=1 -> io limits, finish at 100/1=100
        let a = solve(&proc, &inputs, &opts()).unwrap();
        assert!(close(a.finish_time.unwrap(), 100.0));
        assert_eq!(a.segments[0].bottleneck, Bottleneck::Resource(1));
    }

    /// Resource allocation changes midway: I_R piece boundary event.
    #[test]
    fn allocation_step_change() {
        let proc = ProcessBuilder::new("t", 100.0)
            .stream_resource("cpu", 100.0)
            .build();
        // 1 cpu/s until t=20, then 4 cpu/s
        let inputs = ProcessInputs {
            data: vec![],
            resources: vec![PwPoly::step(0.0, 20.0, 1.0, 4.0)],
            start_time: 0.0,
        };
        // 20 progress by t=20, remaining 80 at 4/s -> +20s: finish 40
        let a = solve(&proc, &inputs, &opts()).unwrap();
        assert!(close(a.finish_time.unwrap(), 40.0), "{:?}", a.finish_time);
        assert!(close(a.progress.eval(20.0), 20.0));
        assert!(close(a.progress.eval(30.0), 60.0));
    }

    /// Data-limited then resource-limited: the paper's crossover case.
    #[test]
    fn data_then_resource_crossover() {
        // data arrives fast early then slows; cpu constant
        let proc = ProcessBuilder::new("t", 100.0)
            .stream_data("in", 100.0) // 1 progress per byte
            .stream_resource("cpu", 100.0) // 1 cpu per progress
            .build();
        // data: 2 B/s for 30 s (60 B), then 0.5 B/s
        let inputs = ProcessInputs {
            data: vec![PwPoly::new(
                vec![0.0, 30.0, 110.0, f64::INFINITY],
                vec![
                    crate::pwfn::poly::Poly::linear(0.0, 2.0),
                    crate::pwfn::poly::Poly::linear(60.0, 0.5),
                    crate::pwfn::poly::Poly::constant(100.0),
                ],
            )],
            resources: vec![PwPoly::constant(1.0)],
            start_time: 0.0,
        };
        // cpu allows 1 progress/s; data allows 2/s early: cpu is the
        // bottleneck until data curve falls below cpu line.
        // P grows at 1/s until it meets PD: PD(t)=min(2t,...); P=t < 2t so
        // cpu-limited until PD flattens: at t=30 PD=60 > P=30; P stays
        // cpu-limited until P catches PD: t such that t = 60+0.5(t-30)
        // => 0.5t = 45 => t=90, P=90. then data-limited at 0.5/s until 100:
        // t = 90 + 10/0.5 = 110.
        let a = solve(&proc, &inputs, &opts()).unwrap();
        assert!(close(a.finish_time.unwrap(), 110.0), "{:?}", a.finish_time);
        assert_eq!(a.bottleneck_at(50.0), Some(Bottleneck::Resource(0)));
        assert_eq!(a.bottleneck_at(100.0), Some(Bottleneck::Data(0)));
        assert!(close(a.progress.eval(90.0), 90.0));
    }

    /// No resources at all: progress follows the data envelope exactly,
    /// including its jump.
    #[test]
    fn unconstrained_follows_pd() {
        let proc = ProcessBuilder::new("t", 100.0)
            .burst_data("in", 10.0)
            .build();
        let inputs = ProcessInputs {
            data: vec![PwPoly::ramp_to(0.0, 1.0, 10.0)],
            resources: vec![],
            start_time: 0.0,
        };
        let a = solve(&proc, &inputs, &opts()).unwrap();
        assert!(close(a.finish_time.unwrap(), 10.0), "{:?}", a.finish_time);
        assert!(close(a.progress.eval(9.0), 0.0));
        assert!(close(a.progress.eval(10.0), 100.0));
    }

    /// Burst *resource* requirement: stall until the allocation integral
    /// covers the up-front cost.
    #[test]
    fn burst_resource_stalls() {
        let proc = ProcessBuilder::new("t", 100.0)
            .burst_resource("cpu", 10.0) // 10 cpu-s before any progress
            .stream_resource("cpu2", 100.0)
            .build();
        let inputs = ProcessInputs {
            data: vec![],
            resources: vec![PwPoly::constant(2.0), PwPoly::constant(1.0)],
            start_time: 0.0,
        };
        // stall 10/2 = 5 s, then 100 progress at 1/s
        let a = solve(&proc, &inputs, &opts()).unwrap();
        assert!(close(a.finish_time.unwrap(), 105.0), "{:?}", a.finish_time);
        assert!(close(a.progress.eval(5.0), 0.0));
        assert_eq!(a.bottleneck_at(2.0), Some(Bottleneck::Resource(0)));
        assert_eq!(a.bottleneck_at(50.0), Some(Bottleneck::Resource(1)));
    }

    /// Never enough data: finish_time = None, progress plateaus.
    #[test]
    fn unfinishable_returns_none() {
        let proc = ProcessBuilder::new("t", 100.0)
            .stream_data("in", 1000.0)
            .build();
        let inputs = ProcessInputs {
            data: vec![PwPoly::constant(500.0)], // only half the input ever
            resources: vec![],
            start_time: 0.0,
        };
        let a = solve(&proc, &inputs, &opts()).unwrap();
        assert_eq!(a.finish_time, None);
        assert!(close(a.progress.eval(1e7), 50.0));
    }

    /// Start time offsets the whole analysis.
    #[test]
    fn start_time_respected() {
        let proc = ProcessBuilder::new("t", 10.0)
            .stream_resource("cpu", 10.0)
            .build();
        let inputs = ProcessInputs {
            data: vec![],
            resources: vec![PwPoly::constant(1.0)],
            start_time: 100.0,
        };
        let a = solve(&proc, &inputs, &opts()).unwrap();
        assert!(close(a.finish_time.unwrap(), 110.0));
        assert!(close(a.progress.eval(100.0), 0.0));
        assert!(close(a.progress.eval(105.0), 5.0));
    }

    /// Zero allocation forever: horizon reached, no finish.
    #[test]
    fn zero_allocation_never_finishes() {
        let proc = ProcessBuilder::new("t", 10.0)
            .stream_resource("cpu", 10.0)
            .build();
        let inputs = ProcessInputs {
            data: vec![],
            resources: vec![PwPoly::constant(0.0)],
            start_time: 0.0,
        };
        let mut o = opts();
        o.horizon = 1e6;
        let a = solve(&proc, &inputs, &o).unwrap();
        assert_eq!(a.finish_time, None);
        assert!(close(a.progress.eval(1000.0), 0.0));
    }

    /// Instantly-complete process.
    #[test]
    fn nop_process() {
        let proc = crate::model::process::Process::nop("nop");
        let inputs = ProcessInputs {
            data: vec![],
            resources: vec![],
            start_time: 3.0,
        };
        let a = solve(&proc, &inputs, &opts()).unwrap();
        assert_eq!(a.finish_time, Some(3.0));
    }

    /// Quadratic data input (the paper's Fig 3 'data2'): the solver handles
    /// polynomial pieces, not just linear ones.
    #[test]
    fn quadratic_data_input() {
        let proc = ProcessBuilder::new("t", 100.0)
            .stream_data("in", 100.0)
            .stream_resource("cpu", 1e-6) // effectively unconstrained
            .build();
        // I_D(t) = t^2/4, reaches 100 at t=20
        let inputs = ProcessInputs {
            data: vec![PwPoly::new(
                vec![0.0, 20.0, f64::INFINITY],
                vec![
                    crate::pwfn::poly::Poly::new(vec![0.0, 0.0, 0.25]),
                    crate::pwfn::poly::Poly::constant(100.0),
                ],
            )],
            resources: vec![PwPoly::constant(1.0)],
            start_time: 0.0,
        };
        let a = solve(&proc, &inputs, &opts()).unwrap();
        assert!(close(a.finish_time.unwrap(), 20.0), "{:?}", a.finish_time);
        assert!(close(a.progress.eval(10.0), 25.0));
    }
}
