//! Deducing the behavior of a process (paper §3).
//!
//! * [`data_progress`] — `P_Dk = R_Dk ∘ I_Dk` and the min-envelope `P_D`;
//! * [`exact`] — Algorithm 2, the event-driven exact solver (the system's
//!   hot path);
//! * [`grid`] — Algorithm 1, the generic discretized reference solver;
//! * [`analysis`] — results: `P(t)`, bottleneck segments, §3.3 metrics.

pub mod analysis;
pub mod data_progress;
pub mod exact;
pub mod grid;

pub use analysis::{Analysis, Bottleneck, Segment};
pub use exact::{solve, SolveError, SolverOpts};
pub use grid::{solve_grid, GridSolution};
