//! Algorithm 1: the generic iterative solver (paper §3.2), discretized.
//!
//! This is the assumption-free reference implementation: it works for any
//! requirement functions (not just piecewise-linear resource requirements)
//! by discretizing time and iterating the fixpoint
//! `P ← min(P_D, ∫ P'·min_l S_Rl dt)` forward. It is *slow by design* —
//! cost scales with the grid, exactly the behaviour the event-driven
//! Algorithm 2 ([`super::exact`]) avoids — and serves three purposes:
//! cross-validation of the exact solver, the ablation bench
//! (Algorithm 1 vs Algorithm 2), and the semantics blueprint for the
//! batched L2 JAX artifact (`python/compile/model.py` implements the same
//! forward pass as a `lax.scan`).

use crate::model::process::{Process, ProcessInputs};

use super::data_progress::data_envelope;

/// Result of the grid solver.
#[derive(Clone, Debug)]
pub struct GridSolution {
    pub ts: Vec<f64>,
    pub progress: Vec<f64>,
    pub finish_time: Option<f64>,
}

/// Forward-integrate progress on a uniform grid of `n_steps` over
/// `[start, start+span]`.
///
/// Semantics mirror the exact solver: per step, the progress increment is
/// capped by every resource's speed limit `I_Rl(t)/R'_Rl(p)` and by the data
/// envelope `P_D`; jumps in `R_Rl` are "paid off" by accumulating the
/// allocation before progress passes the jump point.
pub fn solve_grid(
    process: &Process,
    inputs: &ProcessInputs,
    span: f64,
    n_steps: usize,
) -> GridSolution {
    let t0 = inputs.start_time;
    let (_, pd) = data_envelope(process, inputs);
    let dres: Vec<_> = process
        .res_reqs
        .iter()
        .map(|r| r.func.derivative())
        .collect();
    // jump table per resource: (p_at_jump, height)
    let jumps: Vec<Vec<(f64, f64)>> = process
        .res_reqs
        .iter()
        .map(|r| {
            r.func
                .breaks
                .iter()
                .copied()
                .filter(|b| b.is_finite())
                .filter_map(|b| {
                    let j = r.func.jump_at(b);
                    if j > 1e-12 {
                        Some((b, j))
                    } else {
                        None
                    }
                })
                .collect()
        })
        .collect();

    let dt = span / n_steps as f64;
    let mut ts = Vec::with_capacity(n_steps + 1);
    let mut ps = Vec::with_capacity(n_steps + 1);
    let mut p = 0.0f64.min(process.max_progress);
    // outstanding jump debt per resource (resource-amount still to pay)
    let mut debt = vec![0.0f64; dres.len()];
    // which jumps have already been taken on as debt
    let mut paid: Vec<Vec<bool>> = jumps.iter().map(|js| vec![false; js.len()]).collect();
    let mut finish = None;
    ts.push(t0);
    ps.push(p);
    let tolp = 1e-9 * (1.0 + process.max_progress);

    if process.max_progress <= tolp {
        finish = Some(t0);
    }

    for i in 0..n_steps {
        let t = t0 + i as f64 * dt;
        let t_next = t + dt;
        let mut p_next = if finish.is_some() {
            p
        } else {
            // per-resource speed limit at (t, p)
            let mut max_dp = f64::INFINITY;
            for (l, d) in dres.iter().enumerate() {
                // pay down jump debt first
                if debt[l] > 0.0 {
                    let pay = inputs.resources[l].eval(t) * dt;
                    debt[l] -= pay;
                    if debt[l] > 0.0 {
                        max_dp = 0.0;
                        continue;
                    }
                }
                let c = d.eval(p + tolp);
                if c > 1e-15 {
                    max_dp = max_dp.min(inputs.resources[l].eval(t) * dt / c);
                }
            }
            let cap = pd.func.eval(t_next).min(process.max_progress);
            (p + max_dp.max(0.0)).min(cap)
        };
        // crossing a jump in some R_Rl: clamp at the jump and take on debt
        if finish.is_none() {
            for (l, js) in jumps.iter().enumerate() {
                for (j, &(pj, height)) in js.iter().enumerate() {
                    if !paid[l][j] && p_next >= pj - tolp {
                        p_next = p_next.min(pj);
                        debt[l] += height;
                        paid[l][j] = true;
                    }
                }
            }
        }
        p = p_next;
        if finish.is_none() && p >= process.max_progress - tolp {
            finish = Some(t_next);
            p = process.max_progress;
        }
        ts.push(t_next);
        ps.push(p);
    }

    GridSolution {
        ts,
        progress: ps,
        finish_time: finish,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builder::ProcessBuilder;
    use crate::pwfn::PwPoly;
    use crate::solver::exact::{solve, SolverOpts};

    fn agree(proc: &Process, inputs: &ProcessInputs, span: f64) {
        let exact = solve(proc, inputs, &SolverOpts::default()).unwrap();
        let grid = solve_grid(proc, inputs, span, 20_000);
        // finish times agree to grid resolution
        match (exact.finish_time, grid.finish_time) {
            (Some(a), Some(b)) => {
                let dt = span / 20_000.0;
                assert!(
                    (a - b).abs() <= 3.0 * dt + 1e-9,
                    "exact {a} vs grid {b} (dt {dt})"
                );
            }
            (a, b) => panic!("finish mismatch: exact {a:?} grid {b:?}"),
        }
        // pointwise agreement within Euler error
        for i in (0..grid.ts.len()).step_by(997) {
            let t = grid.ts[i];
            let pe = exact.progress.eval(t);
            let pg = grid.progress[i];
            assert!(
                (pe - pg).abs() <= 1e-2 * (1.0 + pe.abs()),
                "at t={t}: exact {pe} vs grid {pg}"
            );
        }
    }

    #[test]
    fn grid_matches_exact_cpu_bound() {
        let proc = ProcessBuilder::new("t", 100.0)
            .stream_data("in", 1000.0)
            .stream_resource("cpu", 50.0)
            .build();
        let inputs = ProcessInputs {
            data: vec![PwPoly::constant(1000.0)],
            resources: vec![PwPoly::constant(1.0)],
            start_time: 0.0,
        };
        agree(&proc, &inputs, 80.0);
    }

    #[test]
    fn grid_matches_exact_crossover() {
        let proc = ProcessBuilder::new("t", 100.0)
            .stream_data("in", 100.0)
            .stream_resource("cpu", 100.0)
            .build();
        let inputs = ProcessInputs {
            data: vec![PwPoly::new(
                vec![0.0, 30.0, 110.0, f64::INFINITY],
                vec![
                    crate::pwfn::poly::Poly::linear(0.0, 2.0),
                    crate::pwfn::poly::Poly::linear(60.0, 0.5),
                    crate::pwfn::poly::Poly::constant(100.0),
                ],
            )],
            resources: vec![PwPoly::constant(1.0)],
            start_time: 0.0,
        };
        agree(&proc, &inputs, 150.0);
    }

    #[test]
    fn grid_matches_exact_burst_data() {
        let proc = ProcessBuilder::new("t", 100.0)
            .burst_data("in", 1000.0)
            .stream_resource("cpu", 50.0)
            .build();
        let inputs = ProcessInputs {
            data: vec![PwPoly::ramp_to(0.0, 100.0, 1000.0)],
            resources: vec![PwPoly::constant(1.0)],
            start_time: 0.0,
        };
        agree(&proc, &inputs, 100.0);
    }

    #[test]
    fn grid_matches_exact_burst_resource() {
        let proc = ProcessBuilder::new("t", 100.0)
            .burst_resource("cpu", 10.0)
            .stream_resource("cpu2", 100.0)
            .build();
        let inputs = ProcessInputs {
            data: vec![],
            resources: vec![PwPoly::constant(2.0), PwPoly::constant(1.0)],
            start_time: 0.0,
        };
        agree(&proc, &inputs, 150.0);
    }

    #[test]
    fn grid_handles_unfinishable() {
        let proc = ProcessBuilder::new("t", 100.0)
            .stream_data("in", 1000.0)
            .build();
        let inputs = ProcessInputs {
            data: vec![PwPoly::constant(500.0)],
            resources: vec![],
            start_time: 0.0,
        };
        let g = solve_grid(&proc, &inputs, 100.0, 1000);
        assert_eq!(g.finish_time, None);
        assert!((g.progress.last().unwrap() - 50.0).abs() < 1e-6);
    }
}
