//! Sensitivity-report throughput: the full ranked-knob + confidence-band
//! analysis (docs/SENSITIVITY.md) over
//!
//! * the paper's Fig 5 video workflow (8 tasks, every scenario knob), and
//! * a 10³-node generated layered graph (the fixed-model scale knobs),
//!
//! each run twice against one shared analysis cache. The repeat must be
//! answered mostly from memory (hit rate ≥ 50% — in practice ~100%) and
//! must reproduce the first report byte-for-byte: the cache and the
//! stencil batch may change the speed, never the numbers.
//!
//! Asserts can be downgraded to reporting with
//! `BOTTLEMOD_BENCH_NO_ASSERT=1` (e.g. on loaded CI machines).
//!
//! Run: `cargo bench --bench sensitivity`

use std::sync::Arc;
use std::time::Instant;

use bottlemod::runtime::{AnalysisCache, FixedWorkflow, SweepModel};
use bottlemod::sense::{analyze, SenseOpts};
use bottlemod::util::harness::write_bench_artifact;
use bottlemod::util::json::Json;
use bottlemod::util::stats::fmt_duration;
use bottlemod::util::Rng;
use bottlemod::workflow::generator::{generate, GeneratorOpts, Topology};
use bottlemod::workflow::scenario::VideoScenario;

const LARGE_NODES: usize = 1000;

/// Two timed reports against one shared cache; returns
/// `(cold_wall, warm_wall, warm_hit_rate, identical, knobs, events)`.
fn run_pair(
    label: &str,
    model: &Arc<dyn SweepModel>,
    residuals: &[f64],
) -> (f64, f64, f64, bool, usize, usize) {
    let cache = Arc::new(AnalysisCache::new());
    let opts = SenseOpts {
        cache: Some(Arc::clone(&cache)),
        ..SenseOpts::default()
    };
    let t0 = Instant::now();
    let first = analyze(model, residuals, &opts).expect("first report");
    let cold = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let second = analyze(model, residuals, &opts).expect("second report");
    let warm = t0.elapsed().as_secs_f64();

    let hit_rate = second.cache.as_ref().map(|c| c.hit_rate()).unwrap_or(0.0);
    let identical = first.to_json().to_string() == second.to_json().to_string();
    println!(
        "{label}: cold {} -> warm {} ({:.1}x), warm hit rate {:.0}%, \
         {} knobs, {} events, byte-identical repeat: {identical}",
        fmt_duration(cold),
        fmt_duration(warm),
        cold / warm.max(1e-12),
        hit_rate * 100.0,
        first.knobs.len(),
        first.events,
    );
    (cold, warm, hit_rate, identical, first.knobs.len(), first.events)
}

fn main() {
    let no_assert = std::env::var("BOTTLEMOD_BENCH_NO_ASSERT").is_ok();

    // Fig 5: every scenario knob, with synthetic calibration residuals so
    // the band re-solves are part of the measured work.
    let video: Arc<dyn SweepModel> = Arc::new(VideoScenario::default());
    let video_tasks = video.base_workflow().nodes.len();
    let residuals = vec![0.05; video_tasks];
    let (video_cold, video_warm, video_hits, video_same, video_knobs, video_events) =
        run_pair("video (fig 5)", &video, &residuals);

    // 10³-node layered graph wrapped as a fixed model: the scale knobs.
    let gopts = GeneratorOpts {
        topology: Topology::Layered,
        width_jitter: 0.2,
        pool_residual_prob: 0.3,
        ..GeneratorOpts::default()
    }
    .target_nodes(LARGE_NODES);
    let wf = generate(&mut Rng::new(42), &gopts);
    let large_nodes = wf.nodes.len();
    let large: Arc<dyn SweepModel> = Arc::new(FixedWorkflow::new("layered-1k", wf));
    let (large_cold, large_warm, large_hits, large_same, large_knobs, large_events) =
        run_pair(&format!("layered ({large_nodes} nodes)"), &large, &[]);

    let deterministic = video_same && large_same;
    let warm_cache = video_hits >= 0.5 && large_hits >= 0.5;
    if !no_assert {
        assert!(
            deterministic,
            "a repeated report must be byte-identical (video {video_same}, large {large_same})"
        );
        assert!(
            warm_cache,
            "the repeat must hit the shared cache at >= 50% \
             (video {video_hits:.2}, large {large_hits:.2})"
        );
        assert!(video_knobs >= 8, "video exposes {video_knobs} knobs, expected 8+");
        assert!(large_knobs >= 2, "fixed models expose the two scale knobs");
    }
    println!(
        "acceptance: deterministic={deterministic} warm_cache={warm_cache}{}",
        if no_assert { " (reported only)" } else { "" }
    );

    match write_bench_artifact(
        "sensitivity",
        vec![
            ("video_tasks", Json::Num(video_tasks as f64)),
            ("video_knobs", Json::Num(video_knobs as f64)),
            ("video_events", Json::Num(video_events as f64)),
            ("video_cold_wall_s", Json::Num(video_cold)),
            ("video_warm_wall_s", Json::Num(video_warm)),
            ("video_warm_hit_rate", Json::Num(video_hits)),
            ("large_nodes", Json::Num(large_nodes as f64)),
            ("large_knobs", Json::Num(large_knobs as f64)),
            ("large_events", Json::Num(large_events as f64)),
            ("large_cold_wall_s", Json::Num(large_cold)),
            ("large_warm_wall_s", Json::Num(large_warm)),
            ("large_warm_hit_rate", Json::Num(large_hits)),
            ("deterministic", Json::Bool(deterministic)),
        ],
    ) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench artifact: {e}"),
    }
}
