//! Batch-evaluation backend bench: 256 scenario curves × a 1 000-point
//! shared grid, the sweep/sensitivity sampling shape. Compares the scalar
//! per-point loop (`PwPoly::eval` per function per point — a binary
//! search plus `Vec<Poly>` pointer chasing each time) against the
//! structure-of-arrays backend (`pwfn::BatchPwPoly`): one contiguous
//! compile, then `eval_scenarios` / `eval_grid` with galloping piece
//! lookup.
//!
//! Acceptance (ROADMAP item 5): the batch path is **≥ 5×** the scalar
//! loop on the 256-scenario grid, and every batch result is bit-for-bit
//! the scalar value. The speedup assert can be downgraded to reporting
//! with `BOTTLEMOD_BENCH_NO_ASSERT=1` (e.g. on loaded CI machines); the
//! bit-identity asserts always run — determinism is not load-dependent.
//! Results are persisted as `BENCH_batch.json` at the repo root (the perf
//! trajectory, docs/PERF.md).
//!
//! Run: `cargo bench --bench pwfn_batch`

use bottlemod::pwfn::{poly::Poly, BatchPwPoly, PwPoly};
use bottlemod::util::harness::{bench, write_bench_artifact};
use bottlemod::util::json::Json;
use bottlemod::util::Rng;

const SCENARIOS: usize = 256;
const POINTS: usize = 1_000;
const PIECES: usize = 64;
const DEGREE: usize = 2;

/// Random piecewise polynomial with `pieces` pieces, jumps between them,
/// and an infinite constant-extended tail — the sweep-outcome curve shape.
fn random_pw(rng: &mut Rng, pieces: usize, degree: usize) -> PwPoly {
    let mut breaks = Vec::with_capacity(pieces + 1);
    breaks.push(0.0);
    for i in 0..pieces - 1 {
        let prev = breaks[i];
        breaks.push(prev + rng.range(0.5, 3.0));
    }
    breaks.push(f64::INFINITY);
    let polys = (0..pieces)
        .map(|_| Poly::new((0..=degree).map(|_| rng.range(-2.0, 2.0)).collect()))
        .collect();
    PwPoly::new(breaks, polys)
}

fn main() {
    let no_assert = std::env::var("BOTTLEMOD_BENCH_NO_ASSERT").is_ok();
    let mut rng = Rng::new(0x5EED_B47C);

    let fns: Vec<PwPoly> = (0..SCENARIOS).map(|_| random_pw(&mut rng, PIECES, DEGREE)).collect();
    let refs: Vec<&PwPoly> = fns.iter().collect();
    // sorted shared grid spanning past both domain ends (left-clamp and
    // constant-tail regions included)
    let span = 3.0 * PIECES as f64;
    let xs: Vec<f64> = (0..POINTS)
        .map(|j| -2.0 + (span + 4.0) * j as f64 / (POINTS - 1) as f64)
        .collect();

    // ---- bit-identity: asserted unconditionally ---------------------------
    let scalar_ref: Vec<f64> = fns
        .iter()
        .flat_map(|f| xs.iter().map(|&x| f.eval(x)))
        .collect();
    let batch = BatchPwPoly::compile(&refs);
    let scen = batch.eval_scenarios(&xs);
    assert_eq!(scen.len(), scalar_ref.len());
    for (i, (&a, &b)) in scalar_ref.iter().zip(&scen).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "eval_scenarios diverges from scalar at flat index {i}"
        );
    }
    let grid = batch.eval_grid(&xs);
    for i in 0..SCENARIOS {
        for j in 0..POINTS {
            assert_eq!(
                grid[j * SCENARIOS + i].to_bits(),
                scen[i * POINTS + j].to_bits(),
                "eval_grid is not the transpose at ({i}, {j})"
            );
        }
    }
    println!("bit-identity: batch == scalar on all {} values", scen.len());

    // ---- timings ----------------------------------------------------------
    let mut results = vec![];
    let scalar = bench("scalar eval loop 256 fns x 1k pts", 5, || {
        fns.iter()
            .flat_map(|f| xs.iter().map(|&x| f.eval(x)))
            .collect::<Vec<f64>>()
    });
    results.push(scalar.clone());
    let b_scen = bench("batch eval_scenarios 256 x 1k", 5, || {
        batch.eval_scenarios(&xs)
    });
    results.push(b_scen.clone());
    let b_grid = bench("batch eval_grid 256 x 1k", 5, || batch.eval_grid(&xs));
    results.push(b_grid.clone());
    let b_cold = bench("compile + eval_scenarios (cold)", 5, || {
        BatchPwPoly::compile(&refs).eval_scenarios(&xs)
    });
    results.push(b_cold.clone());
    let single = bench("eval_many 1 fn x 1k (vs scalar sample)", 5, || {
        fns[0].eval_many(&xs)
    });
    results.push(single);

    println!("\n== pwfn batch benchmarks ==");
    for r in &results {
        println!("{}", r.report());
    }

    let speedup = scalar.per_iter.mean / b_scen.per_iter.mean;
    let speedup_grid = scalar.per_iter.mean / b_grid.per_iter.mean;
    println!(
        "speedup over scalar loop: eval_scenarios {speedup:.2}x, eval_grid {speedup_grid:.2}x"
    );
    if no_assert {
        if speedup < 5.0 {
            println!("WARN: speedup {speedup:.2}x below the 5x target (assert downgraded)");
        }
    } else {
        assert!(
            speedup >= 5.0,
            "batch eval_scenarios must be >= 5x the scalar loop, got {speedup:.2}x"
        );
    }

    let path = write_bench_artifact(
        "batch",
        vec![
            ("scenarios", Json::Num(SCENARIOS as f64)),
            ("points", Json::Num(POINTS as f64)),
            ("pieces_per_fn", Json::Num(PIECES as f64)),
            ("coeff_width", Json::Num(batch.coeff_width() as f64)),
            ("scalar_s", Json::Num(scalar.per_iter.mean)),
            ("batch_scenarios_s", Json::Num(b_scen.per_iter.mean)),
            ("batch_grid_s", Json::Num(b_grid.per_iter.mean)),
            ("compile_plus_eval_s", Json::Num(b_cold.per_iter.mean)),
            ("speedup", Json::Num(speedup)),
            ("bit_identical", Json::Bool(true)),
        ],
    )
    .expect("write BENCH_batch.json");
    println!("wrote {}", path.display());
}
