//! Fig 7 regeneration bench: the 600-prioritization sweep through
//! (a) the exact engine single-threaded, (b) the exact engine across all
//! cores, (c) the batched PJRT L2/L1 path, plus the per-point testbed cost
//! for contrast (measurement is what the model replaces).
//!
//! Run: `make artifacts && cargo bench --bench fig7_sweep`

use bottlemod::coordinator::sweeper::{best_fraction, exact_sweep, fig7_fractions};
use bottlemod::runtime::{fig7_sweep, Runtime};
use bottlemod::testbed::video::VideoTestbed;
use bottlemod::util::harness::bench_once;
use bottlemod::util::stats::fmt_duration;
use bottlemod::workflow::scenario::VideoScenario;

fn main() {
    let sc = VideoScenario::default();
    let fractions = fig7_fractions(600);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    let mut results = vec![];
    results.push(bench_once("exact sweep 600 cfgs, 1 thread", 5, || {
        exact_sweep(&sc, &fractions, 1)
    }));
    if threads > 1 {
        results.push(bench_once(
            &format!("exact sweep 600 cfgs, {threads} threads"),
            5,
            || exact_sweep(&sc, &fractions, threads),
        ));
    }

    match Runtime::new(&Runtime::default_dir()) {
        _ if !Runtime::backend_available() => {
            eprintln!("(skipping PJRT bench: no execution backend in this build)")
        }
        Ok(mut rt) => {
            // warm the executable cache (compile once)
            let _ = fig7_sweep(&mut rt, &sc, &fractions).expect("pjrt sweep");
            results.push(bench_once("pjrt batched sweep 600 cfgs", 5, || {
                fig7_sweep(&mut rt, &sc, &fractions).unwrap()
            }));
        }
        Err(e) => eprintln!("(skipping PJRT bench: {e})"),
    }

    // what a single real measurement costs on the virtual testbed
    let tb = VideoTestbed::new(sc.clone().with_fraction(0.5));
    results.push(bench_once("testbed execution (1 run, dt=20ms)", 3, || {
        tb.run(None)
    }));

    println!("\n== Fig 7 sweep benchmarks ==");
    for r in &results {
        println!("{}", r.report());
    }

    let sweep = exact_sweep(&sc, &fractions, threads);
    let (bf, bt) = best_fraction(&sweep);
    println!(
        "sweep sanity: best fraction {bf:.3} -> {bt:.1} s; per-config exact cost {}",
        fmt_duration(results[0].per_iter.mean / 600.0)
    );
}
