//! Fig 7 regeneration bench: the 600-prioritization sweep through
//! (a) the exact engine single-threaded, (b) the exact engine across all
//! cores, (c) a batched grid materialization — the PJRT L2/L1 path when an
//! execution backend is built in, otherwise the pure-Rust CPU batch
//! backend (`pwfn::BatchPwPoly::eval_scenarios`, the same B-wide × T-point
//! grid shape) — plus the per-point testbed cost for contrast (measurement
//! is what the model replaces).
//!
//! Run: `make artifacts && cargo bench --bench fig7_sweep`

use std::sync::Arc;

use bottlemod::coordinator::sweeper::{best_fraction, exact_sweep, fig7_fractions};
use bottlemod::pwfn::{BatchPwPoly, PwPoly};
use bottlemod::runtime::sweep::SweepBatch;
use bottlemod::runtime::{fig7_sweep, Runtime};
use bottlemod::testbed::video::VideoTestbed;
use bottlemod::util::harness::bench_once;
use bottlemod::util::stats::fmt_duration;
use bottlemod::workflow::scenario::{Perturbation, VideoScenario};

fn main() {
    let sc = VideoScenario::default();
    let fractions = fig7_fractions(600);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    let mut results = vec![];
    results.push(bench_once("exact sweep 600 cfgs, 1 thread", 5, || {
        exact_sweep(&sc, &fractions, 1)
    }));
    if threads > 1 {
        results.push(bench_once(
            &format!("exact sweep 600 cfgs, {threads} threads"),
            5,
            || exact_sweep(&sc, &fractions, threads),
        ));
    }

    match Runtime::new(&Runtime::default_dir()) {
        _ if !Runtime::backend_available() => {
            // CPU fallback for the batched path: solve the 600 scenarios
            // once with the exact engine, then benchmark materializing the
            // final-node progress of all 600 on the T=2048 shared grid —
            // the very grid the PJRT artifact stages, realized by the SoA
            // batch backend with no artifacts at all.
            let perts: Vec<Perturbation> = fractions
                .iter()
                .map(|&f| Perturbation::Fraction(f))
                .collect();
            let outcomes = SweepBatch::new(Arc::new(sc.clone()))
                .with_threads(threads)
                .run(&perts)
                .expect("exact sweep for CPU batch fallback");
            let span = outcomes.iter().filter_map(|o| o.makespan).fold(0.0_f64, f64::max) + 5.0;
            let ts: Vec<f64> = (0..bottlemod::runtime::xla_sweep::T)
                .map(|i| span * i as f64 / (bottlemod::runtime::xla_sweep::T - 1) as f64)
                .collect();
            let curves: Vec<&PwPoly> = outcomes
                .iter()
                .map(|o| &o.analyses.last().expect("nonempty workflow").progress)
                .collect();
            let batch = BatchPwPoly::compile(&curves);
            // the backend's contract: bit-for-bit the scalar evaluator
            let grid = batch.eval_scenarios(&ts);
            for (i, c) in curves.iter().enumerate() {
                for (j, &t) in ts.iter().enumerate() {
                    assert_eq!(grid[i * ts.len() + j].to_bits(), c.eval(t).to_bits());
                }
            }
            results.push(bench_once("cpu batch grid 600 cfgs x 2048 pts", 5, || {
                batch.eval_scenarios(&ts)
            }));
        }
        Ok(mut rt) => {
            // warm the executable cache (compile once)
            let _ = fig7_sweep(&mut rt, &sc, &fractions).expect("pjrt sweep");
            results.push(bench_once("pjrt batched sweep 600 cfgs", 5, || {
                fig7_sweep(&mut rt, &sc, &fractions).unwrap()
            }));
        }
        Err(e) => eprintln!("(skipping PJRT bench: {e})"),
    }

    // what a single real measurement costs on the virtual testbed
    let tb = VideoTestbed::new(sc.clone().with_fraction(0.5));
    results.push(bench_once("testbed execution (1 run, dt=20ms)", 3, || {
        tb.run(None)
    }));

    println!("\n== Fig 7 sweep benchmarks ==");
    for r in &results {
        println!("{}", r.report());
    }

    let sweep = exact_sweep(&sc, &fractions, threads);
    let (bf, bt) = best_fraction(&sweep);
    println!(
        "sweep sanity: best fraction {bf:.3} -> {bt:.1} s; per-config exact cost {}",
        fmt_duration(results[0].per_iter.mean / 600.0)
    );
}
