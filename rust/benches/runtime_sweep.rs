//! PJRT runtime benchmarks: artifact compile + execute cost for the L1
//! kernel artifact and the L2 grid-solver artifact (the batched hot path).
//!
//! Run: `make artifacts && cargo bench --bench runtime_sweep`

use bottlemod::runtime::Runtime;
use bottlemod::util::harness::bench_once;

const BIG: f32 = 1e30;

fn main() {
    if !Runtime::backend_available() {
        eprintln!("PJRT execution backend not compiled in; nothing to bench");
        return;
    }
    let mut rt = match Runtime::new(&Runtime::default_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifacts not built ({e}); run `make artifacts` first");
            return;
        }
    };
    let mut results = vec![];

    // ---- compile costs (one-time, amortized over the process lifetime) --
    for name in [
        "eval_pw_b64_s16_d4_t1024",
        "grid_solve_pd_b600_k2_l2_s4_t2048",
    ] {
        let t0 = std::time::Instant::now();
        rt.ensure_compiled(name).expect("compile");
        println!(
            "compile {name}: {:.1} ms",
            t0.elapsed().as_secs_f64() * 1e3
        );
    }

    // ---- L1 kernel artifact: 64 functions x 1024 grid points ------------
    {
        let (b, s, d, t) = (64usize, 16usize, 4usize, 1024usize);
        let mut breaks = vec![BIG; b * (s + 1)];
        let mut coeffs = vec![0f32; b * s * d];
        for i in 0..b {
            breaks[i * (s + 1)] = 0.0;
            breaks[i * (s + 1) + 1] = 10.0 + i as f32;
            coeffs[i * s * d + 1] = 1.5; // ramp
            coeffs[i * s * d + d] = 15.0 + 1.5 * i as f32;
        }
        let ts: Vec<f32> = (0..t).map(|i| i as f32 * 0.1).collect();
        let shapes: [&[usize]; 3] = [&[b, s + 1], &[b, s, d], &[t]];
        results.push(bench_once("eval_pw artifact (64x1024)", 10, || {
            rt.execute_f32(
                "eval_pw_b64_s16_d4_t1024",
                &[
                    (&breaks, shapes[0]),
                    (&coeffs, shapes[1]),
                    (&ts, shapes[2]),
                ],
            )
            .unwrap()
        }));
    }

    // ---- L2 grid-solver artifact: one batched stage ----------------------
    {
        use bottlemod::runtime::xla_sweep::{B, K, L, S2, T};
        let pd = vec![100.0f32; B * K * T];
        let mut rbreaks = vec![BIG; B * L * (S2 + 1)];
        let mut rslopes = vec![0f32; B * L * S2];
        for bb in 0..B {
            rbreaks[bb * L * (S2 + 1)] = 0.0;
            rslopes[bb * L * S2] = 1.0;
        }
        let rin = vec![1.0f32; B * L * T];
        let ts: Vec<f32> = (0..T).map(|i| i as f32 * 0.25).collect();
        let target = vec![100.0f32; B];
        let name = format!("grid_solve_pd_b{B}_k{K}_l{L}_s{S2}_t{T}");
        results.push(bench_once("grid_solve_pd stage (600x2048 scan)", 10, || {
            rt.execute_f32(
                &name,
                &[
                    (&pd, &[B, K, T]),
                    (&rbreaks, &[B, L, S2 + 1]),
                    (&rslopes, &[B, L, S2]),
                    (&rin, &[B, L, T]),
                    (&ts, &[T]),
                    (&target, &[B]),
                ],
            )
            .unwrap()
        }));
    }

    println!("\n== PJRT runtime benchmarks ==");
    for r in &results {
        println!("{}", r.report());
    }
}
