//! Ablations over the design choices DESIGN.md calls out:
//!
//! * pool-release fixpoint: single pass (the paper's §5.2 procedure) vs
//!   iterated passes — accuracy and cost across the Fig 7 fraction range;
//! * DES chunk size: the §6 baseline's cost/accuracy knob;
//! * grid resolution of Algorithm 1: error vs steps against the exact
//!   Algorithm 2.
//!
//! Run: `cargo bench --bench ablations`

use bottlemod::des;
use bottlemod::model::{ProcessBuilder, ProcessInputs};
use bottlemod::pwfn::PwPoly;
use bottlemod::solver::{solve, solve_grid, SolverOpts};
use bottlemod::util::harness::bench_once;
use bottlemod::util::stats::ascii_table;
use bottlemod::workflow::engine::{analyze, analyze_fixpoint};
use bottlemod::workflow::scenario::VideoScenario;

fn main() {
    let opts = SolverOpts::default();

    // ---- fixpoint ablation ------------------------------------------------
    println!("== ablation: single-pass (§5.2) vs fixpoint pool release ==");
    let mut rows = vec![vec![
        "fraction".into(),
        "single-pass (s)".into(),
        "fixpoint (s)".into(),
        "passes".into(),
        "testbed truth (s)".into(),
    ]];
    for f in [0.1, 0.3, 0.5, 0.7, 0.93] {
        let sc = VideoScenario::default().with_fraction(f);
        let (wf, _) = sc.build();
        let one = analyze(&wf, &opts).unwrap().makespan.unwrap();
        let wa = analyze_fixpoint(&wf, &opts, 6).unwrap();
        let truth = bottlemod::testbed::video::VideoTestbed::new(sc).run(None).total;
        rows.push(vec![
            format!("{f:.2}"),
            format!("{one:.1}"),
            format!("{:.1}", wa.makespan.unwrap()),
            format!("{}", wa.passes),
            format!("{truth:.1}"),
        ]);
    }
    print!("{}", ascii_table(&rows));
    println!("(below 0.5 the single pass misses the release of task 1's download)\n");

    // ---- DES chunk-size ablation ------------------------------------------
    println!("== ablation: DES chunk size (cost vs granularity) ==");
    let sc = VideoScenario::default();
    let mut rows = vec![vec![
        "chunk".into(),
        "makespan (s)".into(),
        "events".into(),
        "sim time".into(),
    ]];
    for chunk in [16e6, 4e6, 1e6, 0.25e6] {
        let b = bench_once(&format!("des chunk {chunk}"), 3, || {
            des::video::run(&sc, chunk)
        });
        let r = des::video::run(&sc, chunk);
        rows.push(vec![
            format!("{:.2} MB", chunk / 1e6),
            format!("{:.1}", r.makespan),
            format!("{}", r.events),
            format!("{:.2} ms", b.per_iter.mean * 1e3),
        ]);
    }
    print!("{}", ascii_table(&rows));
    println!("(event count and cost scale inversely with chunk size — §6)\n");

    // ---- Algorithm 1 grid-resolution ablation ------------------------------
    println!("== ablation: Algorithm 1 steps vs error (vs exact Algorithm 2) ==");
    let proc = ProcessBuilder::new("t", 100.0)
        .stream_data("in", 100.0)
        .stream_resource("cpu", 100.0)
        .build();
    let inputs = ProcessInputs {
        data: vec![PwPoly::new(
            vec![0.0, 30.0, 110.0, f64::INFINITY],
            vec![
                bottlemod::pwfn::Poly::linear(0.0, 2.0),
                bottlemod::pwfn::Poly::linear(60.0, 0.5),
                bottlemod::pwfn::Poly::constant(100.0),
            ],
        )],
        resources: vec![PwPoly::constant(1.0)],
        start_time: 0.0,
    };
    let exact = solve(&proc, &inputs, &opts).unwrap().finish_time.unwrap();
    let mut rows = vec![vec![
        "steps".into(),
        "finish (s)".into(),
        "error vs exact".into(),
        "time".into(),
    ]];
    for n in [100, 1000, 10_000, 100_000] {
        let b = bench_once(&format!("grid {n}"), 3, || {
            solve_grid(&proc, &inputs, 150.0, n)
        });
        let g = solve_grid(&proc, &inputs, 150.0, n);
        rows.push(vec![
            format!("{n}"),
            format!("{:.3}", g.finish_time.unwrap()),
            format!("{:+.3}", g.finish_time.unwrap() - exact),
            format!("{:.3} ms", b.per_iter.mean * 1e3),
        ]);
    }
    print!("{}", ascii_table(&rows));
    println!("(exact event-driven solver: {exact:.3} s at microsecond cost — the §4 payoff)");
}
