//! §6 scaling, two axes:
//!
//! 1. **Data volume** (the paper's headline): BottleMod analysis vs the
//!    WRENCH-like DES on the Fig 5 workflow as input size grows. The
//!    paper's numbers: BottleMod 20.0 ms (flat: 22.8 ms at 100 GB); WRENCH
//!    32.8 ms at 1.1 GB, 1.137 s at 100 GB. Absolute values differ on this
//!    substrate — the *shape* (flat vs data-scaling) is the claim.
//! 2. **Topology size** (docs/SCALING.md): generated DAGs from 10² to 10⁴
//!    nodes (layered, deep chain, pool-heavy scatter/gather), solved with
//!    the worklist fixpoint under a piece budget. Reports nodes vs solve
//!    time vs peak piece count; hard-asserts (always — deterministic) that
//!    the worklist is bit-for-bit the full fixpoint at 100 nodes and that
//!    every budgeted materialized input respects the cap.
//!
//! Results are persisted as `BENCH_scaling.json` at the repo root (perf
//! trajectory across PRs); a previous artifact, if present, is compared
//! against. Perf-ratio asserts can be downgraded to reporting with
//! `BOTTLEMOD_BENCH_NO_ASSERT=1`.
//!
//! Run: `cargo bench --bench sec6_scaling`

use bottlemod::des;
use bottlemod::solver::SolverOpts;
use bottlemod::util::harness::{bench_once, read_bench_artifact, write_bench_artifact};
use bottlemod::util::json::Json;
use bottlemod::util::stats::ascii_table;
use bottlemod::util::Rng;
use bottlemod::workflow::engine::{analyze_fixpoint, analyze_fixpoint_full};
use bottlemod::workflow::generator::{generate, GeneratorOpts, Topology};
use bottlemod::workflow::scenario::VideoScenario;
use bottlemod::workflow::{Workflow, WorkflowAnalysis};

const PIECE_BUDGET: usize = 128;

fn main() {
    let assert_ok = std::env::var("BOTTLEMOD_BENCH_NO_ASSERT").is_err();
    data_volume_section();
    let results = topology_section(assert_ok);
    persist(&results);
}

/// Axis 1: fixed workflow, growing data volume (flat for BottleMod).
fn data_volume_section() {
    let opts = SolverOpts::default();
    let sizes_gb = [1.1, 5.0, 10.0, 50.0, 100.0];

    let mut rows = vec![vec![
        "input".to_string(),
        "BottleMod mean".to_string(),
        "BM events".to_string(),
        "DES mean".to_string(),
        "DES events".to_string(),
        "DES/BM".to_string(),
    ]];

    let mut first_des = 0.0;
    let mut last_des = 0.0;
    let mut first_bm = 0.0;
    let mut last_bm = 0.0;
    for &gb in &sizes_gb {
        let sc = VideoScenario::default()
            .with_input_size(gb * 1e9)
            .with_fraction(0.5);
        let (wf, _) = sc.build();

        let bm = bench_once(&format!("bottlemod {gb} GB"), 10, || {
            analyze_fixpoint(&wf, &opts, 6).unwrap()
        });
        let bm_events = analyze_fixpoint(&wf, &opts, 6).unwrap().events;

        let des_b = bench_once(&format!("des {gb} GB"), 3, || {
            des::video::run(&sc, 1e6)
        });
        let des_events = des::video::run(&sc, 1e6).events;

        rows.push(vec![
            format!("{gb:.1} GB"),
            format!("{:.3} ms", bm.per_iter.mean * 1e3),
            format!("{bm_events}"),
            format!("{:.3} ms", des_b.per_iter.mean * 1e3),
            format!("{des_events}"),
            format!("{:.0}x", des_b.per_iter.mean / bm.per_iter.mean),
        ]);
        if gb == sizes_gb[0] {
            first_des = des_b.per_iter.mean;
            first_bm = bm.per_iter.mean;
        }
        last_des = des_b.per_iter.mean;
        last_bm = bm.per_iter.mean;
    }

    println!("\n== §6: analysis cost vs input size (Fig 5 workflow, 50:50) ==");
    print!("{}", ascii_table(&rows));
    println!(
        "scaling 1.1 GB -> 100 GB: BottleMod {:.2}x, DES {:.1}x  (paper: ~1.1x vs ~35x)",
        last_bm / first_bm,
        last_des / first_des
    );
}

struct ScalePoint {
    shape: Topology,
    nodes: usize,
    solve_s: f64,
    peak_pieces: usize,
    events: usize,
    passes: usize,
    budget_err: f64,
}

fn gen_opts(shape: Topology, nodes: usize) -> GeneratorOpts {
    let base = match shape {
        // wide shared pool: residual capacity growth is what the piece
        // budget exists for
        Topology::ScatterGather => GeneratorOpts {
            topology: shape,
            width: 40,
            pool_residual_prob: 0.5,
            ..GeneratorOpts::default()
        },
        _ => GeneratorOpts {
            topology: shape,
            width_jitter: 0.15,
            pool_residual_prob: 0.25,
            ..GeneratorOpts::default()
        },
    };
    base.target_nodes(nodes)
}

fn build(shape: Topology, nodes: usize) -> Workflow {
    let mut rng = Rng::new(0x5CA1E + nodes as u64);
    generate(&mut rng, &gen_opts(shape, nodes))
}

fn peak_pieces(wa: &WorkflowAnalysis) -> usize {
    let inp = wa
        .inputs
        .iter()
        .flat_map(|i| i.data.iter().chain(i.resources.iter()))
        .map(|f| f.n_pieces())
        .max()
        .unwrap_or(0);
    let prog = wa
        .analyses
        .iter()
        .map(|a| a.progress.n_pieces())
        .max()
        .unwrap_or(0);
    inp.max(prog)
}

/// Axis 2: generated topologies from 10² to 10⁴ nodes under the worklist
/// fixpoint + piece budget.
fn topology_section(assert_ok: bool) -> Vec<ScalePoint> {
    let opts = SolverOpts {
        piece_budget: PIECE_BUDGET,
        piece_budget_err: 1e-6,
        ..SolverOpts::default()
    };

    // (shape, node axis): the 10⁴ point rides the cheap-per-node shapes;
    // the pool-heavy shape stops at 400 (its residual algebra is the
    // worst case the budget is for, quadratic in pool population)
    let axes: [(Topology, &[usize]); 3] = [
        (Topology::Layered, &[100, 1000, 10_000]),
        (Topology::ChainedStages, &[100, 1000, 10_000]),
        (Topology::ScatterGather, &[100, 400]),
    ];

    // bit-for-bit: worklist vs full reference fixpoint at the small size.
    // Deterministic, so this asserts even under BOTTLEMOD_BENCH_NO_ASSERT.
    for (shape, _) in &axes {
        let wf = build(*shape, 100);
        let fast = analyze_fixpoint(&wf, &opts, 6).unwrap();
        let full = analyze_fixpoint_full(&wf, &opts, 6).unwrap();
        assert_eq!(
            fast.analyses,
            full.analyses,
            "{}: worklist deviates from the reference fixpoint",
            shape.name()
        );
        assert_eq!(fast.events, full.events, "{}: event accounting", shape.name());
        assert_eq!(fast.passes, full.passes, "{}: pass count", shape.name());
    }
    println!("\n== generated-topology scaling (worklist fixpoint, budget {PIECE_BUDGET}) ==");
    println!("bit-for-bit: worklist == full fixpoint on all shapes at 100 nodes ✓");

    let mut rows = vec![vec![
        "shape".to_string(),
        "nodes".to_string(),
        "solve".to_string(),
        "peak pieces".to_string(),
        "events".to_string(),
        "passes".to_string(),
        "budget err".to_string(),
    ]];
    let mut out = vec![];
    for (shape, sizes) in axes {
        for &n in sizes {
            let wf = build(shape, n);
            let nodes = wf.nodes.len();
            let samples = if nodes >= 10_000 { 1 } else { 3 };
            let b = bench_once(&format!("{} {nodes} nodes", shape.name()), samples, || {
                analyze_fixpoint(&wf, &opts, 6).unwrap()
            });
            let wa = analyze_fixpoint(&wf, &opts, 6).unwrap();
            assert!(wa.makespan.is_some(), "{}/{nodes}: never finishes", shape.name());
            // the budget is a hard cap on every materialized input —
            // deterministic, always asserted
            for (i, inp) in wa.inputs.iter().enumerate() {
                for f in inp.data.iter().chain(inp.resources.iter()) {
                    assert!(
                        f.n_pieces() <= PIECE_BUDGET,
                        "{}/{nodes}: node {i} input has {} pieces (cap {PIECE_BUDGET})",
                        shape.name(),
                        f.n_pieces()
                    );
                }
            }
            let point = ScalePoint {
                shape,
                nodes,
                solve_s: b.per_iter.mean,
                peak_pieces: peak_pieces(&wa),
                events: wa.events,
                passes: wa.passes,
                budget_err: wa.budget_err,
            };
            rows.push(vec![
                shape.name().to_string(),
                format!("{nodes}"),
                format!("{:.2} ms", point.solve_s * 1e3),
                format!("{}", point.peak_pieces),
                format!("{}", point.events),
                format!("{}", point.passes),
                format!("{:.2e}", point.budget_err),
            ]);
            out.push(point);
        }
    }
    print!("{}", ascii_table(&rows));

    // the pool-heavy shape must actually trigger the budget (otherwise
    // this bench stops guarding the mechanism it exists for)
    let triggered = out
        .iter()
        .any(|p| p.shape == Topology::ScatterGather && p.budget_err > 0.0);
    assert!(
        triggered,
        "piece budget never triggered on the pool-heavy shape — axis misconfigured"
    );

    // per-node cost must stay roughly flat from 10² to 10⁴ (the §6 claim
    // applied to topology size); generous factor to absorb machine noise
    for shape in [Topology::Layered, Topology::ChainedStages] {
        let pts: Vec<&ScalePoint> = out.iter().filter(|p| p.shape == shape).collect();
        let small = pts.first().unwrap();
        let big = pts.last().unwrap();
        let per_node_ratio =
            (big.solve_s / big.nodes as f64) / (small.solve_s / small.nodes as f64);
        println!(
            "{}: per-node cost ratio {}→{} nodes: {per_node_ratio:.2}x",
            shape.name(),
            small.nodes,
            big.nodes
        );
        if assert_ok {
            assert!(
                per_node_ratio < 50.0,
                "{}: per-node cost blew up {per_node_ratio:.1}x from {} to {} nodes",
                shape.name(),
                small.nodes,
                big.nodes
            );
        }
    }
    out
}

fn persist(points: &[ScalePoint]) {
    if let Some(prev) = read_bench_artifact("scaling") {
        for p in points {
            let key = format!("{}_{}_s", p.shape.name(), p.nodes);
            if let Some(prev_s) = prev.get(&key).as_f64() {
                if prev_s > 0.0 {
                    println!(
                        "perf trajectory {key}: {:.2} ms (previous run) -> {:.2} ms ({:.2}x)",
                        prev_s * 1e3,
                        p.solve_s * 1e3,
                        prev_s / p.solve_s
                    );
                }
            }
        }
    }
    let mut fields: Vec<(String, Json)> = vec![
        ("piece_budget".to_string(), Json::Num(PIECE_BUDGET as f64)),
    ];
    for p in points {
        let base = format!("{}_{}", p.shape.name(), p.nodes);
        fields.push((format!("{base}_s"), Json::Num(p.solve_s)));
        fields.push((format!("{base}_peak_pieces"), Json::Num(p.peak_pieces as f64)));
        fields.push((format!("{base}_events"), Json::Num(p.events as f64)));
        fields.push((format!("{base}_passes"), Json::Num(p.passes as f64)));
    }
    let borrowed: Vec<(&str, Json)> = fields
        .iter()
        .map(|(k, v)| (k.as_str(), v.clone()))
        .collect();
    match write_bench_artifact("scaling", borrowed) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench artifact: {e}"),
    }
}
