//! §6 performance comparison: BottleMod analysis vs the WRENCH-like DES,
//! as a function of simulated input size. The paper's numbers: BottleMod
//! 20.0 ms (flat: 22.8 ms at 100 GB); WRENCH 32.8 ms at 1.1 GB, 1.137 s at
//! 100 GB. Absolute values differ on this substrate — the *shape* (flat vs
//! data-scaling) is the claim under test.
//!
//! Run: `cargo bench --bench sec6_scaling`

use bottlemod::des;
use bottlemod::solver::SolverOpts;
use bottlemod::util::harness::bench_once;
use bottlemod::util::stats::ascii_table;
use bottlemod::workflow::engine::analyze_fixpoint;
use bottlemod::workflow::scenario::VideoScenario;

fn main() {
    let opts = SolverOpts::default();
    let sizes_gb = [1.1, 5.0, 10.0, 50.0, 100.0];

    let mut rows = vec![vec![
        "input".to_string(),
        "BottleMod mean".to_string(),
        "BM events".to_string(),
        "DES mean".to_string(),
        "DES events".to_string(),
        "DES/BM".to_string(),
    ]];

    let mut first_des = 0.0;
    let mut last_des = 0.0;
    let mut first_bm = 0.0;
    let mut last_bm = 0.0;
    for &gb in &sizes_gb {
        let sc = VideoScenario::default()
            .with_input_size(gb * 1e9)
            .with_fraction(0.5);
        let (wf, _) = sc.build();

        let bm = bench_once(&format!("bottlemod {gb} GB"), 10, || {
            analyze_fixpoint(&wf, &opts, 6).unwrap()
        });
        let bm_events = analyze_fixpoint(&wf, &opts, 6).unwrap().events;

        let des_b = bench_once(&format!("des {gb} GB"), 3, || {
            des::video::run(&sc, 1e6)
        });
        let des_events = des::video::run(&sc, 1e6).events;

        rows.push(vec![
            format!("{gb:.1} GB"),
            format!("{:.3} ms", bm.per_iter.mean * 1e3),
            format!("{bm_events}"),
            format!("{:.3} ms", des_b.per_iter.mean * 1e3),
            format!("{des_events}"),
            format!("{:.0}x", des_b.per_iter.mean / bm.per_iter.mean),
        ]);
        if gb == sizes_gb[0] {
            first_des = des_b.per_iter.mean;
            first_bm = bm.per_iter.mean;
        }
        last_des = des_b.per_iter.mean;
        last_bm = bm.per_iter.mean;
    }

    println!("\n== §6: analysis cost vs input size (Fig 5 workflow, 50:50) ==");
    print!("{}", ascii_table(&rows));
    println!(
        "scaling 1.1 GB -> 100 GB: BottleMod {:.2}x, DES {:.1}x  (paper: ~1.1x vs ~35x)",
        last_bm / first_bm,
        last_des / first_des
    );
}
