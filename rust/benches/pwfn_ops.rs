//! Micro-benchmarks of the piecewise-function substrate — the operations
//! Algorithm 2's cost is made of (eval, roots, envelopes, composition,
//! inversion, exact rational PL ops).
//!
//! Run: `cargo bench --bench pwfn_ops`

use bottlemod::pwfn::{poly::Poly, PwLinear, PwPoly, Rat};
use bottlemod::util::harness::{bench, write_bench_artifact};
use bottlemod::util::json::Json;
use bottlemod::util::Rng;

fn random_pwpoly(rng: &mut Rng, pieces: usize, degree: usize) -> PwPoly {
    let mut breaks = vec![0.0];
    for i in 0..pieces - 1 {
        breaks.push(breaks[i] + rng.range(0.5, 3.0));
    }
    breaks.push(f64::INFINITY);
    let polys = (0..pieces)
        .map(|_| Poly::new((0..=degree).map(|_| rng.range(-2.0, 2.0)).collect()))
        .collect();
    PwPoly::new(breaks, polys)
}

fn monotone_pwpoly(rng: &mut Rng, pieces: usize) -> PwPoly {
    // nondecreasing PL function (rates >= 0)
    let mut points = vec![(0.0, 0.0)];
    for i in 0..pieces {
        let (x, y) = points[i];
        points.push((x + rng.range(0.5, 2.0), y + rng.range(0.0, 3.0)));
    }
    PwPoly::from_points(&points)
}

fn main() {
    let mut rng = Rng::new(42);
    let f8 = random_pwpoly(&mut rng, 8, 2);
    let f64p = random_pwpoly(&mut rng, 64, 2);
    let g8 = random_pwpoly(&mut rng, 8, 2);
    let g64 = random_pwpoly(&mut rng, 64, 2);
    let m16 = monotone_pwpoly(&mut rng, 16);
    let m16b = monotone_pwpoly(&mut rng, 16);

    let mut results = vec![];
    results.push(bench("eval (8 pieces)", 20, || f8.eval(7.3)));
    results.push(bench("eval (64 pieces)", 20, || f64p.eval(53.1)));
    results.push(bench("min_envelope 2x8", 20, || {
        PwPoly::min_envelope(&[&f8, &g8])
    }));
    results.push(bench("min_envelope 2x64", 20, || {
        PwPoly::min_envelope(&[&f64p, &g64])
    }));
    results.push(bench("compose 16∘16 (monotone)", 20, || {
        m16.compose(&m16b)
    }));
    results.push(bench("inverse_linear (16 pieces)", 20, || {
        m16.inverse_linear().unwrap()
    }));
    results.push(bench("antiderivative (64 pieces)", 20, || {
        f64p.antiderivative(0.0)
    }));
    results.push(bench("first_reach (16 pieces)", 20, || {
        m16.first_reach(m16.eval(20.0) * 0.7, 0.0)
    }));

    // cubic root finding — the costliest primitive the solver may hit
    let cubic = Poly::new(vec![-6.0, 11.0, -6.0, 1.0]);
    results.push(bench("cubic roots_in", 20, || cubic.roots_in(0.0, 4.0)));

    // exact rational PL path
    let r = |n: i64, d: i64| Rat::new(n as i128, d as i128).unwrap();
    let ex_a = PwLinear::from_points(&[
        (Rat::int(0), Rat::int(0)),
        (r(7, 3), r(5, 2)),
        (r(19, 4), r(23, 5)),
        (Rat::int(9), Rat::int(9)),
    ])
    .unwrap();
    let ex_b = PwLinear::linear(Rat::ZERO, r(1, 2), r(3, 7));
    results.push(bench("exact PL min_envelope", 20, || {
        PwLinear::min_envelope(&[&ex_a, &ex_b]).unwrap()
    }));
    results.push(bench("exact PL inverse", 20, || ex_a.inverse().unwrap()));

    println!("\n== pwfn substrate micro-benchmarks ==");
    for r in &results {
        println!("{}", r.report());
    }

    // machine-readable trajectory: mean seconds/iter per op
    let fields: Vec<(&str, Json)> = results
        .iter()
        .map(|r| (r.name.as_str(), Json::Num(r.per_iter.mean)))
        .collect();
    match write_bench_artifact("pwfn_ops", fields) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench artifact: {e}"),
    }
}
