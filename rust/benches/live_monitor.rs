//! Incremental re-analysis vs cold re-runs: stream a synthetic chain
//! trace into a [`Monitor`] one task row per event, then replay the same
//! prefixes through the offline `calibrate_trace` pipeline, and compare
//!
//! * wall time (the monitor's fit memo + dirty-cone solve vs a full
//!   parse → calibrate → solve per prefix),
//! * work (cache misses = nodes actually re-solved incrementally, which
//!   must be a strict subset of the cold pipeline's node solves), and
//! * answers (the live prediction must be bit-for-bit the cold one at
//!   every prefix — speed must not change the numbers).
//!
//! Asserts can be downgraded to reporting with
//! `BOTTLEMOD_BENCH_NO_ASSERT=1` (e.g. on loaded CI machines).
//!
//! Run: `cargo bench --bench live_monitor`

use std::time::Instant;

use bottlemod::live::{Monitor, MonitorOpts};
use bottlemod::solver::SolverOpts;
use bottlemod::trace::{calibrate_trace, CalibrateOpts};
use bottlemod::util::harness::write_bench_artifact;
use bottlemod::util::json::Json;
use bottlemod::util::stats::fmt_duration;

const TASKS: usize = 48;

const HEADER: &str = "task_id\tdeps\tstart\tcomplete\trealtime\tpcpu\trchar\twchar\tpeak_rss";

/// One synthetic pipeline stage: 1e8 bytes streamed through, runtimes
/// staggered so every fit is distinct.
fn row(i: usize) -> String {
    let rt = 8 + (i % 5) as u64;
    let start: u64 = (0..i).map(|j| 8 + (j % 5) as u64).sum();
    let deps = if i == 0 {
        "-".to_string()
    } else {
        format!("t{:03}", i - 1)
    };
    format!(
        "t{i:03}\t{deps}\t{start}\t{}\t{rt}\t100\t1e8\t1e8\t8e6",
        start + rt
    )
}

fn main() {
    let no_assert = std::env::var("BOTTLEMOD_BENCH_NO_ASSERT").is_ok();
    let rows: Vec<String> = (0..TASKS).map(row).collect();

    // phase A: incremental — one monitor, one feed per arriving task row
    let mut m = Monitor::new("bench-chain", None, MonitorOpts::default());
    let mut live_bits: Vec<Option<u64>> = Vec::with_capacity(TASKS);
    let mut misses_total = 0u64;
    let mut hits_after_first = 0u64;
    let mut max_event_misses = 0u64;
    let t0 = Instant::now();
    for (i, r) in rows.iter().enumerate() {
        let chunk = if i == 0 {
            format!("{HEADER}\n{r}\n")
        } else {
            format!("{r}\n")
        };
        let rep = m.feed(Some(&chunk), None).expect("feed");
        assert!(rep.stale.is_none(), "event {i}: stale {:?}", rep.stale);
        live_bits.push(
            rep.snapshot
                .as_ref()
                .and_then(|s| s.makespan)
                .map(f64::to_bits),
        );
        misses_total += rep.cache.misses;
        if i > 0 {
            hits_after_first += rep.cache.hits;
            max_event_misses = max_event_misses.max(rep.cache.misses);
        }
    }
    let incremental_wall = t0.elapsed().as_secs_f64();
    let hit_rate = m.cache().stats().hit_rate();

    // phase B: cold — the offline pipeline re-run from scratch per prefix
    let mut cold_bits: Vec<Option<u64>> = Vec::with_capacity(TASKS);
    let mut cold_node_solves = 0u64;
    let mut prefix = format!("{HEADER}\n");
    let t0 = Instant::now();
    for (i, r) in rows.iter().enumerate() {
        prefix.push_str(r);
        prefix.push('\n');
        let (_, rep) = calibrate_trace(
            &prefix,
            None,
            &CalibrateOpts::default(),
            &SolverOpts::default(),
        )
        .expect("cold pipeline");
        cold_bits.push(rep.predicted_makespan.map(f64::to_bits));
        cold_node_solves += (i + 1) as u64; // a fresh solve visits every node
    }
    let cold_wall = t0.elapsed().as_secs_f64();

    let speedup = cold_wall / incremental_wall.max(1e-12);
    let bit_identical = live_bits == cold_bits;
    println!(
        "incremental: {TASKS} events in {} ({misses_total} node solves, \
         max {max_event_misses}/event after warmup, hit rate {:.0}%)",
        fmt_duration(incremental_wall),
        hit_rate * 100.0
    );
    println!(
        "cold: {TASKS} prefix re-runs in {} (>= {cold_node_solves} node solves)",
        fmt_duration(cold_wall)
    );
    println!("speedup: {speedup:.1}x, bit-identical at every prefix: {bit_identical}");

    let subset = misses_total < cold_node_solves;
    let warm = hits_after_first > 0 && hit_rate > 0.0;
    if !no_assert {
        assert!(bit_identical, "live and cold predictions must agree bit-for-bit");
        assert!(
            subset,
            "incremental solve must touch a strict subset of the cold work \
             ({misses_total} vs {cold_node_solves})"
        );
        assert!(
            warm,
            "the analysis cache must be warm after the first event \
             ({hits_after_first} hits, rate {hit_rate})"
        );
    }
    println!(
        "acceptance: bit_identical={bit_identical} strict_subset={subset} cache_warm={warm}{}",
        if no_assert { " (reported only)" } else { "" }
    );

    match write_bench_artifact(
        "live",
        vec![
            ("tasks", Json::Num(TASKS as f64)),
            ("events", Json::Num(TASKS as f64)),
            ("incremental_wall_s", Json::Num(incremental_wall)),
            ("cold_wall_s", Json::Num(cold_wall)),
            ("speedup", Json::Num(speedup)),
            ("incremental_node_solves", Json::Num(misses_total as f64)),
            ("cold_node_solves", Json::Num(cold_node_solves as f64)),
            ("cache_hit_rate", Json::Num(hit_rate)),
            ("bit_identical", Json::Bool(bit_identical)),
        ],
    ) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench artifact: {e}"),
    }
}
