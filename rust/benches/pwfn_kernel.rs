//! Microbenchmarks of the allocation-lean pwfn kernel: add/mul/min/
//! compose/eval on 10–10 000-piece functions, plus the two acceptance
//! properties of the k-way rewrite:
//!
//!  * the single-sweep `min_envelope` beats the retained pairwise fold
//!    (`min_envelope_pairwise`) by **≥ 2×** at k ≥ 8 inputs;
//!  * `eval` on 1 000 pieces behaves like the O(log n) binary search it
//!    is — the 1 000-piece / 10-piece time ratio stays far below the
//!    O(n) ratio (asserted ≤ 10×, vs 100× for a linear scan).
//!
//! Correctness is spot-checked inline (k-way vs pairwise envelope values);
//! the full differential suite lives in `tests/pwfn_differential.rs`.
//! Asserts can be downgraded to reporting with
//! `BOTTLEMOD_BENCH_NO_ASSERT=1`. Results are persisted as
//! `BENCH_pwfn_kernel.json` at the repo root (the perf trajectory).
//!
//! Run: `cargo bench --bench pwfn_kernel`

use bottlemod::pwfn::{poly::Poly, PwPoly};
use bottlemod::util::harness::{bench, write_bench_artifact};
use bottlemod::util::json::Json;
use bottlemod::util::Rng;

/// Random piecewise polynomial (degree ≤ `degree`) with an infinite tail.
fn random_pw(rng: &mut Rng, pieces: usize, degree: usize) -> PwPoly {
    let mut breaks = Vec::with_capacity(pieces + 1);
    breaks.push(0.0);
    for i in 0..pieces - 1 {
        let prev = breaks[i];
        breaks.push(prev + rng.range(0.5, 3.0));
    }
    breaks.push(f64::INFINITY);
    let polys = (0..pieces)
        .map(|_| Poly::new((0..=degree).map(|_| rng.range(-2.0, 2.0)).collect()))
        .collect();
    PwPoly::new(breaks, polys)
}

/// Nondecreasing PL function — the data-envelope workload shape.
fn monotone_pl(rng: &mut Rng, pieces: usize) -> PwPoly {
    let mut points = Vec::with_capacity(pieces + 1);
    points.push((0.0, rng.range(0.0, 2.0)));
    for i in 0..pieces {
        let (x, y) = points[i];
        points.push((x + rng.range(0.5, 2.0), y + rng.range(0.0, 3.0)));
    }
    PwPoly::from_points(&points)
}

fn main() {
    let no_assert = std::env::var("BOTTLEMOD_BENCH_NO_ASSERT").is_ok();
    let mut rng = Rng::new(0x5EED_17);
    let mut results = vec![];
    let mut fields: Vec<(String, f64)> = vec![];

    // ---- eval: O(log n) piece lookup --------------------------------------
    let sizes = [10usize, 100, 1_000, 10_000];
    let mut eval_means = vec![];
    for &n in &sizes {
        let f = random_pw(&mut rng, n, 2);
        let span = f.breaks[n - 1]; // last finite break
        let xs: Vec<f64> = (0..64).map(|i| span * (i as f64 + 0.5) / 64.0).collect();
        let r = bench(&format!("eval x64 ({n} pieces)"), 10, || {
            let mut acc = 0.0;
            for &x in &xs {
                acc += f.eval(x);
            }
            acc
        });
        eval_means.push(r.per_iter.mean);
        fields.push((format!("eval64_{n}p_s"), r.per_iter.mean));
        results.push(r);
    }
    let eval_ratio = eval_means[2] / eval_means[0]; // 1k pieces vs 10 pieces
    fields.push(("eval_ratio_1k_vs_10".to_string(), eval_ratio));

    // ---- binary algebra on big operands -----------------------------------
    let a1k = random_pw(&mut rng, 1_000, 2);
    let b1k = random_pw(&mut rng, 1_000, 2);
    let r = bench("add 1k⊕1k pieces", 10, || a1k.add(&b1k));
    fields.push(("add_1k_s".to_string(), r.per_iter.mean));
    results.push(r);
    let r = bench("mul 1k⊗1k pieces", 10, || a1k.mul(&b1k));
    fields.push(("mul_1k_s".to_string(), r.per_iter.mean));
    results.push(r);

    // ---- compose ----------------------------------------------------------
    let m64 = monotone_pl(&mut rng, 64);
    let m64b = monotone_pl(&mut rng, 64);
    let r = bench("compose 64∘64 (monotone)", 10, || m64.compose(&m64b));
    fields.push(("compose_64_s".to_string(), r.per_iter.mean));
    results.push(r);

    // ---- k-way envelope vs pairwise fold ----------------------------------
    let mut kway_speedups: Vec<(usize, f64)> = vec![];
    for &k in &[4usize, 8, 16] {
        let fns: Vec<PwPoly> = (0..k).map(|_| monotone_pl(&mut rng, 64)).collect();
        let refs: Vec<&PwPoly> = fns.iter().collect();

        // spot-check: the sweep and the fold agree on values and on
        // winner validity at sample points
        let kway = PwPoly::min_envelope(&refs);
        let pair = PwPoly::min_envelope_pairwise(&refs);
        for i in 0..200 {
            let x = 80.0 * i as f64 / 199.0;
            let (kv, pv) = (kway.func.eval(x), pair.func.eval(x));
            assert!(
                (kv - pv).abs() <= 1e-7 * (1.0 + pv.abs()),
                "k-way vs pairwise at k={k}, x={x}: {kv} vs {pv}"
            );
            let wv = fns[kway.winner_at(x)].eval(x);
            assert!(
                (wv - kv).abs() <= 1e-7 * (1.0 + kv.abs()),
                "winner off envelope at k={k}, x={x}"
            );
        }

        let rk = bench(&format!("min_envelope k-way (k={k}, 64p)"), 10, || {
            PwPoly::min_envelope(&refs)
        });
        let rp = bench(&format!("min_envelope pairwise (k={k}, 64p)"), 10, || {
            PwPoly::min_envelope_pairwise(&refs)
        });
        let speedup = rp.per_iter.mean / rk.per_iter.mean;
        kway_speedups.push((k, speedup));
        fields.push((format!("minall_kway_k{k}_s"), rk.per_iter.mean));
        fields.push((format!("minall_pairwise_k{k}_s"), rp.per_iter.mean));
        fields.push((format!("minall_speedup_k{k}"), speedup));
        results.push(rk);
        results.push(rp);
    }

    // ---- sum_all vs pairwise fold -----------------------------------------
    let fns8: Vec<PwPoly> = (0..8).map(|_| random_pw(&mut rng, 64, 2)).collect();
    let refs8: Vec<&PwPoly> = fns8.iter().collect();
    let rk = bench("sum_all k-way (k=8, 64p)", 10, || PwPoly::sum_all(&refs8));
    let rp = bench("sum pairwise fold (k=8, 64p)", 10, || {
        let mut acc = fns8[0].clone();
        for f in &fns8[1..] {
            acc = acc.add(f);
        }
        acc
    });
    let sum_speedup = rp.per_iter.mean / rk.per_iter.mean;
    fields.push(("sumall_speedup_k8".to_string(), sum_speedup));
    results.push(rk);
    results.push(rp);

    // ---- in-place vs pure -------------------------------------------------
    let r = bench("scale (pure, 1k pieces)", 10, || a1k.scale(1.000001));
    fields.push(("scale_pure_1k_s".to_string(), r.per_iter.mean));
    results.push(r);
    let mut scratch = a1k.clone();
    let r = bench("scale_mut (in place, 1k pieces)", 10, || {
        scratch.scale_mut(1.000001)
    });
    fields.push(("scale_mut_1k_s".to_string(), r.per_iter.mean));
    results.push(r);

    println!("\n== pwfn kernel micro-benchmarks ==");
    for r in &results {
        println!("{}", r.report());
    }
    println!(
        "\neval scaling: 1k-piece / 10-piece time ratio {eval_ratio:.2}x \
         (O(n) would be ~100x; binary search keeps it logarithmic)"
    );
    for (k, s) in &kway_speedups {
        println!("k-way envelope speedup over pairwise at k={k}: {s:.2}x");
    }
    println!("k-way sum speedup over pairwise fold at k=8: {sum_speedup:.2}x");

    // ---- acceptance -------------------------------------------------------
    if no_assert {
        println!("\n(asserts downgraded to reporting: BOTTLEMOD_BENCH_NO_ASSERT)");
    } else {
        assert!(
            eval_ratio <= 10.0,
            "eval on 1k pieces should be O(log n) in practice: \
             1k/10-piece ratio {eval_ratio:.2}x > 10x"
        );
        for (k, s) in &kway_speedups {
            if *k >= 8 {
                assert!(
                    *s >= 2.0,
                    "k-way envelope should beat the pairwise fold >= 2x at \
                     k={k}, got {s:.2}x"
                );
            }
        }
        println!("\nacceptance: eval ratio {eval_ratio:.2}x <= 10x, k-way >= 2x at k >= 8 ✓");
    }

    let json_fields: Vec<(&str, Json)> = fields
        .iter()
        .map(|(k, v)| (k.as_str(), Json::Num(*v)))
        .collect();
    match write_bench_artifact("pwfn_kernel", json_fields) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench artifact: {e}"),
    }
}
