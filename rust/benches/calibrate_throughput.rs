//! Trace-calibration throughput: parse + fit a 10 000-row TSV trace (plus
//! I/O series for a subset of tasks) and assert the cold path stays under
//! a second — the budget that keeps `bottlemod calibrate` interactive and
//! the service's `calibrate` op cheap enough to call per scheduling round.
//!
//! Asserts can be downgraded to reporting with
//! `BOTTLEMOD_BENCH_NO_ASSERT=1` (e.g. on loaded CI machines).
//!
//! Run: `cargo bench --bench calibrate_throughput`

use bottlemod::solver::SolverOpts;
use bottlemod::trace::{
    assemble, calibrate, parse_io_log, parse_tsv, replay, CalibrateOpts,
};
use bottlemod::util::harness::{bench_once, write_bench_artifact};
use bottlemod::util::json::Json;
use bottlemod::util::stats::fmt_duration;

const N_TASKS: usize = 10_000;
const CHAIN: usize = 10;
const N_SERIES_TASKS: usize = 100;
const SAMPLES_PER_SERIES: usize = 20;

/// Synthesize a consistent trace: 1 000 independent 10-task chains, each
/// task reading and writing 1e8 B over 10 s of one core, executed staged
/// (every task starts when its predecessor completes). Chain roots look
/// streaming to the memory heuristic, every dependent task burst-shaped —
/// which is also what makes the staged timings replay consistently.
fn synth_tsv() -> String {
    let mut out = String::from(
        "task_id\tname\tdeps\tstart\tcomplete\trealtime\tpcpu\trchar\twchar\tpeak_rss\n",
    );
    for i in 0..N_TASKS {
        let pos = i % CHAIN;
        let deps = if pos == 0 {
            "-".to_string()
        } else {
            format!("t{}", i - 1)
        };
        let start = 10.0 * pos as f64;
        let rss = if pos == 0 { 1e6 } else { 9e7 };
        out.push_str(&format!(
            "t{i}\ttask-{i}\t{deps}\t{start}\t{}\t10\t100\t1e8\t1e8\t{rss:e}\n",
            start + 10.0
        ));
    }
    out
}

/// I/O series for the first tasks: input fully staged at task start
/// (cumulative read already at its total), output growing linearly.
fn synth_io_log() -> String {
    let mut out = String::new();
    for i in 0..N_SERIES_TASKS {
        let pos = i % CHAIN;
        let start = 10.0 * pos as f64;
        for s in 0..=SAMPLES_PER_SERIES {
            let rel = 10.0 * s as f64 / SAMPLES_PER_SERIES as f64;
            out.push_str(&format!("t{i}\t{}\t1e8\t{}\n", start + rel, 1e7 * rel));
        }
    }
    out
}

fn main() {
    let no_assert = std::env::var("BOTTLEMOD_BENCH_NO_ASSERT").is_ok();
    let tsv = synth_tsv();
    let io = synth_io_log();
    println!(
        "trace: {} TSV rows ({} KiB) + {} io samples ({} KiB)",
        N_TASKS,
        tsv.len() / 1024,
        N_SERIES_TASKS * (SAMPLES_PER_SERIES + 1),
        io.len() / 1024
    );

    // the asserted budget: cold parse + fit of every task
    let opts = CalibrateOpts::default();
    let r = bench_once("parse + fit (10k tasks, cold)", 5, || {
        let trace = parse_tsv(&tsv).expect("tsv parses");
        let series = parse_io_log(&io).expect("io log parses");
        let cal = calibrate(&trace, &series, &opts).expect("calibrates");
        assert_eq!(cal.len(), N_TASKS);
        cal
    });
    println!("{}", r.report());

    // the rest of the pipeline, reported for context (not asserted)
    let trace = parse_tsv(&tsv).unwrap();
    let series = parse_io_log(&io).unwrap();
    let tasks = calibrate(&trace, &series, &opts).unwrap();
    let t0 = std::time::Instant::now();
    let cal = assemble(tasks).expect("assembles");
    let report = replay(&cal, &SolverOpts::default()).expect("replays");
    println!(
        "assemble + replay: {} ({} nodes, max rel err {:.3}%)",
        fmt_duration(t0.elapsed().as_secs_f64()),
        cal.workflow.nodes.len(),
        report.max_rel_err.unwrap_or(f64::NAN) * 100.0
    );

    let ok = r.per_iter.mean < 1.0;
    if !ok && !no_assert {
        panic!(
            "cold calibration of {} rows took {} (budget: < 1 s)",
            N_TASKS,
            fmt_duration(r.per_iter.mean)
        );
    }
    println!(
        "acceptance: cold parse+fit {} 1 s budget",
        if ok { "within" } else { "OVER (reported only)" }
    );

    match write_bench_artifact(
        "calibrate_throughput",
        vec![
            ("rows", Json::Num(N_TASKS as f64)),
            ("cold_parse_fit_s", Json::Num(r.per_iter.mean)),
            ("rows_per_s", Json::Num(N_TASKS as f64 / r.per_iter.mean)),
            ("budget_s", Json::Num(1.0)),
            ("within_budget", Json::Bool(ok)),
        ],
    ) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench artifact: {e}"),
    }
}
