//! Algorithm 2 (event-driven exact) vs Algorithm 1 (generic grid) — the §4
//! ablation: what does the piecewise-linear restriction buy?
//!
//! Run: `cargo bench --bench solver_algorithms`

use bottlemod::model::{Process, ProcessBuilder, ProcessInputs};
use bottlemod::pwfn::{poly::Poly, PwPoly};
use bottlemod::solver::{solve, solve_grid, SolverOpts};
use bottlemod::util::harness::bench;
use bottlemod::workflow::engine::analyze_fixpoint;
use bottlemod::workflow::scenario::VideoScenario;

fn crossover_case() -> (Process, ProcessInputs) {
    let proc = ProcessBuilder::new("t", 100.0)
        .stream_data("in", 100.0)
        .stream_resource("cpu", 100.0)
        .build();
    let inputs = ProcessInputs {
        data: vec![PwPoly::new(
            vec![0.0, 30.0, 110.0, f64::INFINITY],
            vec![
                Poly::linear(0.0, 2.0),
                Poly::linear(60.0, 0.5),
                Poly::constant(100.0),
            ],
        )],
        resources: vec![PwPoly::constant(1.0)],
        start_time: 0.0,
    };
    (proc, inputs)
}

fn many_piece_case(n: usize) -> (Process, ProcessInputs) {
    // data input with n pieces (alternating rates): n envelope/limit changes
    let mut points = vec![(0.0, 0.0)];
    for i in 0..n {
        let (x, y) = points[i];
        let rate = if i % 2 == 0 { 2.0 } else { 0.6 };
        points.push((x + 5.0, y + 5.0 * rate));
    }
    let total = points.last().unwrap().1;
    let proc = ProcessBuilder::new("t", total)
        .stream_data("in", total)
        .stream_resource("cpu", total)
        .build();
    let inputs = ProcessInputs {
        data: vec![PwPoly::from_points(&points)],
        resources: vec![PwPoly::constant(1.0)],
        start_time: 0.0,
    };
    (proc, inputs)
}

fn main() {
    let opts = SolverOpts::default();
    let mut results = vec![];

    let (p, i) = crossover_case();
    results.push(bench("Alg2 exact: crossover process", 20, || {
        solve(&p, &i, &opts).unwrap()
    }));
    results.push(bench("Alg1 grid 1k steps: crossover", 20, || {
        solve_grid(&p, &i, 150.0, 1000)
    }));
    results.push(bench("Alg1 grid 20k steps: crossover", 10, || {
        solve_grid(&p, &i, 150.0, 20_000)
    }));

    let mut last_events = 0;
    for n in [8, 32, 128] {
        let (p, i) = many_piece_case(n);
        results.push(bench(&format!("Alg2 exact: {n}-piece input"), 10, || {
            solve(&p, &i, &opts).unwrap()
        }));
        last_events = solve(&p, &i, &opts).unwrap().events;
    }

    // whole-workflow analysis (the paper's unit of work)
    let (wf, _) = VideoScenario::default().build();
    results.push(bench("workflow analysis (Fig 5, fixpoint)", 20, || {
        analyze_fixpoint(&wf, &opts, 6).unwrap()
    }));

    println!("\n== solver algorithm benchmarks ==");
    for r in &results {
        println!("{}", r.report());
    }
    println!(
        "(exact solver cost scales with limit changes, not time steps; \
         128-piece case used {last_events} events)"
    );
}
