//! Multi-session service load: 8 concurrent TCP sessions drive ≥ 1 000
//! mixed requests (ping / analyze / sweep / calibrate) through one shared
//! worker pool, recording client-observed p50/p99 latency; a second phase
//! points 8 simultaneous sweeps at a 1-worker / 1-deep queue and checks
//! that admission control answers with structured `overloaded` errors
//! instead of hanging. Session caches run under a small entry quota so
//! eviction is exercised under load.
//!
//! Asserts can be downgraded to reporting with
//! `BOTTLEMOD_BENCH_NO_ASSERT=1` (e.g. on loaded CI machines).
//!
//! Run: `cargo bench --bench service_load`

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use bottlemod::coordinator::{ServeOpts, Server};
use bottlemod::util::harness::write_bench_artifact;
use bottlemod::util::json::Json;
use bottlemod::util::stats::fmt_duration;

const SESSIONS: usize = 8;
const REQUESTS_PER_SESSION: usize = 150; // 1 200 total across the fleet
const CACHE_QUOTA_ENTRIES: usize = 128;
const OVERLOAD_ROUNDS: usize = 5;

// Mirrors `api::test_fixtures::TINY_SPEC`: one process, makespan 5.
const TINY_SPEC: &str = r#"{
  "processes": [
    {"name": "a", "max_progress": 10.0,
     "data": [{"req": {"type": "stream", "total": 10.0},
               "source": {"external_constant": 10.0}}],
     "resources": [{"req": {"type": "stream", "total": 5.0},
                    "source": {"constant": 1.0}}],
     "outputs": [{"name": "out", "type": "identity"}]}
  ]
}"#;

// Mirrors `api::test_fixtures::CHAIN_TSV`: dl (10 s) → enc (20 s).
const CHAIN_TSV: &str = "task_id\tdeps\tstart\tcomplete\trealtime\tpcpu\trchar\twchar\tpeak_rss\n\
    dl\t-\t0\t10\t10\t1e9\t1e8\t1e8\t2e6\n\
    enc\tdl\t0\t20\t20\t100\t1e8\t5e7\t8e6\n";

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let writer = TcpStream::connect(addr).expect("connect");
        writer
            .set_read_timeout(Some(Duration::from_secs(120)))
            .expect("read timeout");
        let reader = BufReader::new(writer.try_clone().expect("clone"));
        Client { reader, writer }
    }

    fn request(&mut self, line: &str) -> Json {
        writeln!(self.writer, "{line}").expect("send");
        self.writer.flush().expect("flush");
        let mut resp = String::new();
        self.reader.read_line(&mut resp).expect("recv");
        Json::parse(resp.trim()).expect("response parses")
    }
}

fn v1(id: u64, op: &str, extra: Vec<(&str, Json)>) -> String {
    let mut fields = vec![
        ("v", Json::Num(1.0)),
        ("id", Json::Num(id as f64)),
        ("op", Json::Str(op.into())),
    ];
    fields.extend(extra);
    Json::obj(fields).to_string()
}

fn sweep_req(id: u64, fractions: &[f64]) -> String {
    let ps = fractions
        .iter()
        .map(|&f| {
            Json::obj(vec![
                ("kind", Json::Str("fraction".into())),
                ("value", Json::Num(f)),
            ])
        })
        .collect();
    v1(
        id,
        "sweep",
        vec![
            ("workflow", Json::Str("video".into())),
            ("perturbations", Json::Arr(ps)),
        ],
    )
}

/// The mixed request stream of one session: 2/4 cheap ops, 1/4 analyze,
/// 1/4 sweep over per-request-distinct fractions (distinctness is what
/// pushes the quota'd session cache into eviction).
fn mixed_request(session: usize, i: usize) -> String {
    let id = (session * REQUESTS_PER_SESSION + i) as u64;
    match i % 4 {
        0 => v1(id, "ping", vec![]),
        1 => v1(
            id,
            "analyze",
            vec![("spec", Json::parse(TINY_SPEC).expect("spec parses"))],
        ),
        2 => {
            let base = 0.05 + (id % 115) as f64 * 0.008;
            sweep_req(id, &[base, base + 0.001, base + 0.002])
        }
        _ => v1(id, "calibrate", vec![("tsv", Json::Str(CHAIN_TSV.into()))]),
    }
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

struct SessionOutcome {
    latencies: Vec<f64>,
    evictions: f64,
    max_entries: f64,
}

fn load_phase(addr: SocketAddr) -> (Vec<f64>, f64, f64, f64) {
    let barrier = Arc::new(Barrier::new(SESSIONS));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..SESSIONS)
        .map(|s| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                barrier.wait();
                let mut out = SessionOutcome {
                    latencies: Vec::with_capacity(REQUESTS_PER_SESSION),
                    evictions: 0.0,
                    max_entries: 0.0,
                };
                for i in 0..REQUESTS_PER_SESSION {
                    let line = mixed_request(s, i);
                    let t = Instant::now();
                    let resp = c.request(&line);
                    out.latencies.push(t.elapsed().as_secs_f64());
                    assert_eq!(
                        resp.get("ok").as_bool(),
                        Some(true),
                        "request must succeed under nominal load: {resp:?}"
                    );
                    let cache = resp.get("result").get("cache");
                    if let Some(e) = cache.get("evictions").as_f64() {
                        out.evictions += e;
                        let entries = cache.get("entries").as_f64().unwrap_or(0.0);
                        out.max_entries = out.max_entries.max(entries);
                    }
                }
                out
            })
        })
        .collect();
    let mut latencies = Vec::new();
    let mut evictions = 0.0;
    let mut max_entries = 0.0f64;
    for h in handles {
        let o = h.join().expect("no session panics");
        latencies.extend(o.latencies);
        evictions += o.evictions;
        max_entries = max_entries.max(o.max_entries);
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(f64::total_cmp);
    (latencies, wall, evictions, max_entries)
}

fn overload_phase(addr: SocketAddr) -> (u32, u32) {
    let barrier = Arc::new(Barrier::new(SESSIONS));
    let handles: Vec<_> = (0..SESSIONS)
        .map(|s| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                barrier.wait();
                let (mut ok, mut overloaded) = (0u32, 0u32);
                for r in 0..OVERLOAD_ROUNDS {
                    let id = (s * OVERLOAD_ROUNDS + r) as u64;
                    let resp = c.request(&sweep_req(id, &[0.25, 0.5, 0.75, 0.93]));
                    if resp.get("ok").as_bool() == Some(true) {
                        ok += 1;
                    } else {
                        assert_eq!(
                            resp.get("error").get("code").as_str(),
                            Some("overloaded"),
                            "the only expected failure is admission control: {resp:?}"
                        );
                        overloaded += 1;
                    }
                }
                (ok, overloaded)
            })
        })
        .collect();
    let (mut ok, mut overloaded) = (0, 0);
    for h in handles {
        let (o, v) = h.join().expect("no session panics");
        ok += o;
        overloaded += v;
    }
    (ok, overloaded)
}

fn main() {
    let no_assert = std::env::var("BOTTLEMOD_BENCH_NO_ASSERT").is_ok();
    let total = SESSIONS * REQUESTS_PER_SESSION;

    // phase A: nominal load — deep queue, quota'd session caches
    let mut server = Server::new(ServeOpts {
        session_cache_entries: CACHE_QUOTA_ENTRIES,
        ..ServeOpts::default()
    });
    let addr = server.listen_tcp("127.0.0.1:0").expect("bind");
    let (latencies, wall, evictions, max_entries) = load_phase(addr);
    server.shutdown();

    let p50 = percentile(&latencies, 0.50);
    let p99 = percentile(&latencies, 0.99);
    let rps = total as f64 / wall;
    println!(
        "load: {total} mixed requests over {SESSIONS} sessions in {} ({rps:.0} req/s)",
        fmt_duration(wall)
    );
    println!(
        "latency: p50 {}, p99 {}, max {}",
        fmt_duration(p50),
        fmt_duration(p99),
        fmt_duration(percentile(&latencies, 1.0))
    );
    println!(
        "session caches: quota {CACHE_QUOTA_ENTRIES} entries, max resident {max_entries}, \
         {evictions} evictions across the fleet"
    );

    // phase B: overload — 1 worker, 1-deep queue, 8 simultaneous sweeps
    let mut server = Server::new(ServeOpts {
        threads: 1,
        queue_bound: 1,
        ..ServeOpts::default()
    });
    let addr = server.listen_tcp("127.0.0.1:0").expect("bind");
    let (ok, overloaded) = overload_phase(addr);
    server.shutdown();
    println!(
        "overload: {} requests at queue bound 1 -> {ok} ok, {overloaded} overloaded, 0 hung",
        ok + overloaded
    );

    let answered = latencies.len() == total;
    let bounded = max_entries <= CACHE_QUOTA_ENTRIES as f64 && evictions > 0.0;
    let sheds = overloaded >= 1 && ok >= 1;
    if !no_assert {
        assert!(answered, "every request must get exactly one response");
        assert!(
            bounded,
            "session caches must stay within quota and actually evict \
             (max {max_entries}, {evictions} evictions)"
        );
        assert!(
            sheds,
            "a saturated queue must shed load with `overloaded` ({ok} ok, {overloaded} shed)"
        );
    }
    println!(
        "acceptance: answered={answered} cache_bounded={bounded} load_shed={sheds}{}",
        if no_assert { " (reported only)" } else { "" }
    );

    match write_bench_artifact(
        "service",
        vec![
            ("sessions", Json::Num(SESSIONS as f64)),
            ("requests", Json::Num(total as f64)),
            ("wall_s", Json::Num(wall)),
            ("requests_per_s", Json::Num(rps)),
            ("latency_p50_s", Json::Num(p50)),
            ("latency_p99_s", Json::Num(p99)),
            ("cache_quota_entries", Json::Num(CACHE_QUOTA_ENTRIES as f64)),
            ("cache_max_entries", Json::Num(max_entries)),
            ("cache_evictions", Json::Num(evictions)),
            ("overload_ok", Json::Num(ok as f64)),
            ("overload_shed", Json::Num(overloaded as f64)),
        ],
    ) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench artifact: {e}"),
    }
}
