//! The tentpole bench: the batched scenario-sweep engine vs the sequential
//! sweeper on a 256-scenario batch, plus the incremental (cached) engine
//! on a 256-scenario single-node-perturbation batch.
//!
//! Checks the acceptance properties:
//!  * per-scenario results are **bit-for-bit identical** between the
//!    sequential (1-thread) and parallel runs — full `Analysis` equality;
//!  * with ≥ 4 cores the parallel batch achieves ≥ 3× the sequential
//!    throughput;
//!  * on a single-node-perturbation batch the cached sweep's ranked
//!    `BottleneckReport` and every per-scenario `Analysis` are bit-for-bit
//!    equal to the cold sequential run, with **≥ 2×** wall-clock
//!    improvement at a **≥ 50 %** cache hit rate.
//!
//! Asserts can be downgraded to reporting with
//! `BOTTLEMOD_BENCH_NO_ASSERT=1` (e.g. on loaded CI machines); the
//! bit-for-bit checks always assert.
//!
//! Results are persisted as `BENCH_sweep_parallel.json` at the repo root
//! (the perf trajectory across PRs); a previous artifact, if present, is
//! compared against. Setting `BOTTLEMOD_BASELINE_SPS` (scenarios/s of the
//! pre-optimization kernel) additionally asserts a ≥ 1.5× throughput gain.
//!
//! Run: `cargo bench --bench sweep_parallel`

use std::sync::Arc;

use bottlemod::runtime::cache::{AnalysisCache, CacheStats};
use bottlemod::runtime::sweep::{BottleneckReport, SweepBatch};
use bottlemod::util::harness::{bench_once, read_bench_artifact, write_bench_artifact};
use bottlemod::util::json::Json;
use bottlemod::util::par::num_threads;
use bottlemod::util::stats::fmt_duration;
use bottlemod::workflow::scenario::{Perturbation, VideoScenario};

fn batch_of(n: usize) -> Vec<Perturbation> {
    // mostly the Fig 7 fraction axis, with input-rate / resource / model
    // variants mixed in so the batch exercises every perturbation kind
    (0..n)
        .map(|i| match i % 8 {
            5 => Perturbation::LinkRateScale(0.5 + (i % 16) as f64 / 16.0),
            6 => Perturbation::CpuScale(0.5 + (i % 32) as f64 / 16.0),
            7 => Perturbation::Task2Burst,
            _ => Perturbation::Fraction((i + 1) as f64 / (n as f64 + 1.0)),
        })
        .collect()
}

fn main() {
    const N: usize = 256;
    let base = Arc::new(VideoScenario::default());
    let batch = batch_of(N);
    let threads = num_threads();

    // correctness first: identical per-scenario results, any thread count
    let seq_out = SweepBatch::new(base.clone())
        .with_threads(1)
        .run(&batch)
        .expect("sequential sweep");
    let par_out = SweepBatch::new(base.clone())
        .with_threads(threads)
        .run(&batch)
        .expect("parallel sweep");
    assert_eq!(
        seq_out, par_out,
        "parallel sweep must be bit-for-bit identical to sequential"
    );
    println!(
        "determinism: {N} scenarios bit-for-bit identical across 1 vs {threads} threads ✓"
    );

    // throughput
    let seq_batch = SweepBatch::new(base.clone()).with_threads(1);
    let par_batch = SweepBatch::new(base.clone()).with_threads(threads);
    let seq = bench_once(&format!("{N}-scenario sweep, 1 thread"), 3, || {
        seq_batch.run(&batch).unwrap()
    });
    let par = bench_once(&format!("{N}-scenario sweep, {threads} threads"), 3, || {
        par_batch.run(&batch).unwrap()
    });

    println!("\n== batched sweep engine ==");
    println!("{}", seq.report());
    println!("{}", par.report());
    let speedup = seq.per_iter.mean / par.per_iter.mean;
    println!(
        "speedup: {speedup:.2}x on {threads} threads ({} vs {} per {N}-scenario batch)",
        fmt_duration(seq.per_iter.mean),
        fmt_duration(par.per_iter.mean)
    );

    let report = BottleneckReport::aggregate(&par_out);
    println!("\ntop cross-scenario bottlenecks:");
    for r in report.ranked.iter().take(5) {
        println!(
            "  {:>14} / {:<12} {:>10.1} s over {}/{} scenarios",
            r.process, r.bottleneck, r.total_seconds, r.scenarios, report.scenarios
        );
    }

    let assert_ok = std::env::var("BOTTLEMOD_BENCH_NO_ASSERT").is_err();
    if threads >= 4 && assert_ok {
        assert!(
            speedup >= 3.0,
            "expected >= 3x throughput on {threads} threads, got {speedup:.2}x"
        );
        println!("\nacceptance: {speedup:.2}x >= 3x on {threads} threads ✓");
    } else if threads < 4 {
        println!("\n(acceptance assert skipped: only {threads} threads available)");
    }

    let (inc_cold_s, inc_warm_s, cache_stats) = incremental_section(&base, assert_ok);

    // ---- perf trajectory: persist + compare across PRs ------------------
    let scenarios_per_s = N as f64 / par.per_iter.mean;
    if let Some(prev) = read_bench_artifact("sweep_parallel") {
        if let Some(prev_sps) = prev.get("scenarios_per_s").as_f64() {
            println!(
                "\nperf trajectory: {prev_sps:.0} scen/s (previous run) -> \
                 {scenarios_per_s:.0} scen/s ({:.2}x)",
                scenarios_per_s / prev_sps
            );
        }
    }
    if let Ok(base_sps) = std::env::var("BOTTLEMOD_BASELINE_SPS") {
        if let Ok(base_sps) = base_sps.parse::<f64>() {
            let gain = scenarios_per_s / base_sps;
            println!("vs provided baseline: {gain:.2}x over {base_sps:.0} scen/s");
            if assert_ok {
                assert!(
                    gain >= 1.5,
                    "expected >= 1.5x over the pre-optimization baseline \
                     ({base_sps:.0} scen/s), got {gain:.2}x"
                );
                println!("acceptance: {gain:.2}x >= 1.5x over baseline ✓");
            }
        }
    }
    match write_bench_artifact(
        "sweep_parallel",
        vec![
            ("scenarios", Json::Num(N as f64)),
            ("threads", Json::Num(threads as f64)),
            ("seq_batch_s", Json::Num(seq.per_iter.mean)),
            ("par_batch_s", Json::Num(par.per_iter.mean)),
            ("scenarios_per_s", Json::Num(scenarios_per_s)),
            ("speedup_parallel", Json::Num(speedup)),
            ("incremental_cold_s", Json::Num(inc_cold_s)),
            ("incremental_cached_s", Json::Num(inc_warm_s)),
            ("incremental_speedup", Json::Num(inc_cold_s / inc_warm_s)),
            ("cache_hit_rate", Json::Num(cache_stats.hit_rate())),
        ],
    ) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench artifact: {e}"),
    }
}

/// The incremental-engine acceptance: a 256-scenario batch of single-node
/// perturbations (each touches only task 1's CPU model, dirty cone
/// `{task1, task3}`), cold vs cached. Returns `(cold batch s, cached
/// batch s, cache stats)` for the persisted artifact.
fn incremental_section(base: &Arc<VideoScenario>, assert_ok: bool) -> (f64, f64, CacheStats) {
    const N: usize = 256;
    let batch: Vec<Perturbation> = (0..N)
        .map(|i| Perturbation::Task1CpuScale(0.25 + 1.5 * i as f64 / N as f64))
        .collect();

    // correctness first: the cached run (sequential AND parallel) must be
    // bit-for-bit the cold sequential run, report included
    let cold_sweep = SweepBatch::new(base.clone()).with_threads(1);
    let (cold_out, cold_report) = cold_sweep.run_report(&batch).expect("cold sweep");
    let warm_par = SweepBatch::new(base.clone())
        .with_threads(num_threads())
        .with_new_cache();
    let (warm_par_out, warm_par_report) = warm_par.run_report(&batch).expect("warm sweep");
    assert_eq!(
        cold_out, warm_par_out,
        "cached parallel sweep must be bit-for-bit identical to the cold \
         sequential run (every per-scenario Analysis)"
    );
    assert_eq!(
        cold_report.ranked, warm_par_report.ranked,
        "ranked BottleneckReport must be bit-for-bit identical"
    );
    println!(
        "\n== incremental sweep engine ==\n\
         determinism: {N} single-node scenarios bit-for-bit identical, cold vs cached ✓"
    );

    // throughput: cold vs cached, both sequential, so the measured gain is
    // the cache's alone (a fresh cache per iteration: the batch itself must
    // pay for its own warm-up and still win)
    let cold = bench_once(&format!("{N}-scenario cold sweep, 1 thread"), 3, || {
        cold_sweep.run(&batch).unwrap()
    });
    let warm = bench_once(&format!("{N}-scenario cached sweep, 1 thread"), 3, || {
        SweepBatch::new(base.clone())
            .with_threads(1)
            .with_cache(Arc::new(AnalysisCache::new()))
            .run(&batch)
            .unwrap()
    });
    println!("{}", cold.report());
    println!("{}", warm.report());
    let speedup = cold.per_iter.mean / warm.per_iter.mean;
    let stats = warm_par_report.cache.expect("cached run reports stats");
    println!(
        "incremental speedup: {speedup:.2}x ({} cold vs {} cached per {N}-scenario batch)",
        fmt_duration(cold.per_iter.mean),
        fmt_duration(warm.per_iter.mean)
    );
    println!("cache: {stats}");

    if assert_ok {
        assert!(
            stats.hit_rate() >= 0.5,
            "expected >= 50% hit rate on a single-node-perturbation batch, got {:.1}%",
            stats.hit_rate() * 100.0
        );
        assert!(
            speedup >= 2.0,
            "expected >= 2x from incremental re-analysis, got {speedup:.2}x"
        );
        println!(
            "acceptance: {speedup:.2}x >= 2x with {:.1}% >= 50% hit rate ✓",
            stats.hit_rate() * 100.0
        );
    }
    (cold.per_iter.mean, warm.per_iter.mean, stats)
}
