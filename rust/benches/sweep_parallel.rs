//! The tentpole bench: the batched scenario-sweep engine vs the sequential
//! sweeper on a 256-scenario batch.
//!
//! Checks two acceptance properties:
//!  * per-scenario results are **bit-for-bit identical** between the
//!    sequential (1-thread) and parallel runs — full `Analysis` equality;
//!  * with ≥ 4 cores the parallel batch achieves ≥ 3× the sequential
//!    throughput (asserted; set `BOTTLEMOD_BENCH_NO_ASSERT=1` to only
//!    report, e.g. on loaded CI machines).
//!
//! Run: `cargo bench --bench sweep_parallel`

use std::sync::Arc;

use bottlemod::runtime::sweep::{BottleneckReport, SweepBatch};
use bottlemod::util::harness::bench_once;
use bottlemod::util::par::num_threads;
use bottlemod::util::stats::fmt_duration;
use bottlemod::workflow::scenario::{Perturbation, VideoScenario};

fn batch_of(n: usize) -> Vec<Perturbation> {
    // mostly the Fig 7 fraction axis, with input-rate / resource / model
    // variants mixed in so the batch exercises every perturbation kind
    (0..n)
        .map(|i| match i % 8 {
            5 => Perturbation::LinkRateScale(0.5 + (i % 16) as f64 / 16.0),
            6 => Perturbation::CpuScale(0.5 + (i % 32) as f64 / 16.0),
            7 => Perturbation::Task2Burst,
            _ => Perturbation::Fraction((i + 1) as f64 / (n as f64 + 1.0)),
        })
        .collect()
}

fn main() {
    const N: usize = 256;
    let base = Arc::new(VideoScenario::default());
    let batch = batch_of(N);
    let threads = num_threads();

    // correctness first: identical per-scenario results, any thread count
    let seq_out = SweepBatch::new(base.clone())
        .with_threads(1)
        .run(&batch)
        .expect("sequential sweep");
    let par_out = SweepBatch::new(base.clone())
        .with_threads(threads)
        .run(&batch)
        .expect("parallel sweep");
    assert_eq!(
        seq_out, par_out,
        "parallel sweep must be bit-for-bit identical to sequential"
    );
    println!(
        "determinism: {N} scenarios bit-for-bit identical across 1 vs {threads} threads ✓"
    );

    // throughput
    let seq_batch = SweepBatch::new(base.clone()).with_threads(1);
    let par_batch = SweepBatch::new(base.clone()).with_threads(threads);
    let seq = bench_once(&format!("{N}-scenario sweep, 1 thread"), 3, || {
        seq_batch.run(&batch).unwrap()
    });
    let par = bench_once(&format!("{N}-scenario sweep, {threads} threads"), 3, || {
        par_batch.run(&batch).unwrap()
    });

    println!("\n== batched sweep engine ==");
    println!("{}", seq.report());
    println!("{}", par.report());
    let speedup = seq.per_iter.mean / par.per_iter.mean;
    println!(
        "speedup: {speedup:.2}x on {threads} threads ({} vs {} per {N}-scenario batch)",
        fmt_duration(seq.per_iter.mean),
        fmt_duration(par.per_iter.mean)
    );

    let report = BottleneckReport::aggregate(&par_out);
    println!("\ntop cross-scenario bottlenecks:");
    for r in report.ranked.iter().take(5) {
        println!(
            "  {:>14} / {:<12} {:>10.1} s over {}/{} scenarios",
            r.process, r.bottleneck, r.total_seconds, r.scenarios, report.scenarios
        );
    }

    let assert_ok = std::env::var("BOTTLEMOD_BENCH_NO_ASSERT").is_err();
    if threads >= 4 && assert_ok {
        assert!(
            speedup >= 3.0,
            "expected >= 3x throughput on {threads} threads, got {speedup:.2}x"
        );
        println!("\nacceptance: {speedup:.2}x >= 3x on {threads} threads ✓");
    } else if threads < 4 {
        println!("\n(acceptance assert skipped: only {threads} threads available)");
    }
}
