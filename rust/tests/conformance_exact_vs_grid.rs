//! Differential conformance: Algorithm 2 (`solver::exact`) vs Algorithm 1
//! (`solver::grid`) must agree — on randomized single-task scenarios and on
//! the genomics + video example workflows — in finish time, pointwise
//! progress, and per-segment bottleneck attribution.
//!
//! Attribution is checked semantically: inside a segment the exact solver
//! labels `Data(k)`, progress must ride the data envelope; inside a
//! `Resource(l)` segment, the progress slope must equal the allocated rate
//! divided by the marginal cost `R'_Rl(p)`.

use bottlemod::model::{Process, ProcessBuilder, ProcessInputs};
use bottlemod::pwfn::PwPoly;
use bottlemod::solver::{solve, solve_grid, Analysis, Bottleneck, SolverOpts};
use bottlemod::util::harness::check_property;
use bottlemod::util::Rng;
use bottlemod::workflow::engine::analyze_fixpoint;
use bottlemod::workflow::scenario::{GenomicsScenario, VideoScenario};

const GRID_STEPS: usize = 20_000;

/// Random monotone PL cumulative input over [0, ~100] reaching `total`.
fn random_cumulative(rng: &mut Rng, total: f64) -> PwPoly {
    let n = 1 + rng.below(5);
    let mut points = vec![(0.0, 0.0)];
    for i in 0..n {
        let (x, y) = points[i];
        points.push((
            x + rng.range(2.0, 25.0),
            (y + rng.range(0.0, total * 0.6)).min(total),
        ));
    }
    let (x, y) = *points.last().unwrap();
    if y < total {
        points.push((x + rng.range(2.0, 25.0), total));
    }
    PwPoly::from_points(&points)
}

/// Random single process with 1-2 data inputs and 0-2 stream resources.
fn random_scenario(rng: &mut Rng) -> (Process, ProcessInputs) {
    let max_p = rng.range(50.0, 200.0);
    let mut b = ProcessBuilder::new("rand", max_p);
    let k = 1 + rng.below(2);
    let mut data = vec![];
    for i in 0..k {
        let total = rng.range(50.0, 300.0);
        if rng.f64() < 0.3 {
            b = b.burst_data(&format!("d{i}"), total);
        } else {
            b = b.stream_data(&format!("d{i}"), total);
        }
        data.push(random_cumulative(rng, total));
    }
    let l = rng.below(3);
    let mut resources = vec![];
    for i in 0..l {
        b = b.stream_resource(&format!("r{i}"), rng.range(10.0, 120.0));
        let r1 = rng.range(0.2, 3.0);
        let r2 = rng.range(0.2, 3.0);
        let t_switch = rng.range(5.0, 80.0);
        resources.push(PwPoly::step(0.0, t_switch, r1, r2));
    }
    (
        b.identity_output("out").build(),
        ProcessInputs {
            data,
            resources,
            start_time: 0.0,
        },
    )
}

/// Differential check of one (process, inputs) pair. `tag` labels errors.
fn check_agreement(
    process: &Process,
    inputs: &ProcessInputs,
    exact: &Analysis,
    tag: &str,
) -> Result<(), String> {
    let span = exact.finish_time.map(|f| f - inputs.start_time).unwrap_or(500.0) + 20.0;
    let grid = solve_grid(process, inputs, span, GRID_STEPS);
    let dt = span / GRID_STEPS as f64;

    // ---- finish times ---------------------------------------------------
    match (exact.finish_time, grid.finish_time) {
        (Some(a), Some(b)) => {
            if (a - b).abs() > 5.0 * dt + 1e-6 {
                return Err(format!("{tag}: finish exact {a} vs grid {b} (dt {dt})"));
            }
        }
        (None, None) => {}
        (a, b) => return Err(format!("{tag}: finish mismatch exact {a:?} vs grid {b:?}")),
    }

    // ---- pointwise progress --------------------------------------------
    for i in (0..grid.ts.len()).step_by(499) {
        let t = grid.ts[i];
        let pe = exact.progress.eval(t);
        let pg = grid.progress[i];
        let tol = 5.0 * dt * slope_bound(exact, t) + 1e-2 * (1.0 + pe.abs());
        if (pe - pg).abs() > tol {
            return Err(format!("{tag}: at t={t} exact {pe} vs grid {pg}"));
        }
    }

    // ---- bottleneck attribution per segment -----------------------------
    for seg in &exact.segments {
        let end = seg.end.min(exact.finish_time.unwrap_or(f64::INFINITY));
        if !(end - seg.start).is_finite() || end - seg.start < 20.0 * dt {
            continue; // too short to probe numerically
        }
        let t = 0.5 * (seg.start + end);
        let p = exact.progress.eval(t);
        match seg.bottleneck {
            Bottleneck::Data(_) => {
                // data-limited: progress rides the envelope
                let pd = exact.pd.func.eval(t);
                if (p - pd).abs() > 1e-6 * (1.0 + pd.abs()) + 1e-9 {
                    return Err(format!(
                        "{tag}: Data segment at t={t} has P={p} off envelope {pd}"
                    ));
                }
            }
            Bottleneck::Resource(l) => {
                // stalls (flat progress while paying a jump) are legitimate
                let slope = exact.progress.slope_right(t);
                if slope.abs() < 1e-12 {
                    continue;
                }
                let alloc = inputs.resources[l].eval(t);
                let cost = process.res_reqs[l].func.derivative().eval(p + 1e-9);
                if cost > 1e-12 {
                    let want = alloc / cost;
                    if (slope - want).abs() > 1e-3 * (1.0 + want.abs()) {
                        return Err(format!(
                            "{tag}: Resource({l}) segment at t={t}: P'={slope} vs I/R'={want}"
                        ));
                    }
                }
            }
            Bottleneck::None => {}
        }
    }
    Ok(())
}

/// Max |P'| near t, to convert grid time-error into progress-error.
fn slope_bound(exact: &Analysis, t: f64) -> f64 {
    exact
        .progress
        .slope_right(t)
        .abs()
        .max(exact.progress.slope_right((t - 1e-6).max(exact.start_time)).abs())
}

#[test]
fn randomized_single_task_conformance() {
    check_property("exact == grid on random scenarios", 60, |rng| {
        let (p, inputs) = random_scenario(rng);
        let exact = solve(&p, &inputs, &SolverOpts::default())
            .map_err(|e| format!("solve: {e}"))?;
        check_agreement(&p, &inputs, &exact, "random")
    });
}

#[test]
fn video_workflow_conformance() {
    for f in [0.5, 0.95] {
        let sc = VideoScenario::default().with_fraction(f);
        let (wf, _) = sc.build();
        let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 6).unwrap();
        for (i, a) in wa.analyses.iter().enumerate() {
            let node = &wf.nodes[i];
            check_agreement(
                &node.process,
                &wa.inputs[i],
                a,
                &format!("video f={f} node {}", node.process.name),
            )
            .unwrap_or_else(|e| panic!("{e}"));
        }
    }
}

#[test]
fn genomics_workflow_conformance() {
    let wf = GenomicsScenario::default().build();
    let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 6).unwrap();
    for (i, a) in wa.analyses.iter().enumerate() {
        let node = &wf.nodes[i];
        check_agreement(
            &node.process,
            &wa.inputs[i],
            a,
            &format!("genomics node {}", node.process.name),
        )
        .unwrap_or_else(|e| panic!("{e}"));
    }
}
