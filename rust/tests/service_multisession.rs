//! Multi-session socket-server integration tests: N concurrent clients
//! against one [`Server`], interleaved v1 ops, per-session response
//! ordering, structured `overloaded` under a tiny queue bound, and
//! session caches bounded by their quota.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use bottlemod::coordinator::{ServeOpts, Server};
use bottlemod::util::Json;

// Mirrors `api::test_fixtures::TINY_SPEC` (cfg(test) lib items are not
// visible to integration tests): a one-process spec solving to makespan 5.
const TINY_SPEC: &str = r#"{
  "processes": [
    {"name": "a", "max_progress": 10.0,
     "data": [{"req": {"type": "stream", "total": 10.0},
               "source": {"external_constant": 10.0}}],
     "resources": [{"req": {"type": "stream", "total": 5.0},
                    "source": {"constant": 1.0}}],
     "outputs": [{"name": "out", "type": "identity"}]}
  ]
}"#;

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let writer = TcpStream::connect(addr).unwrap();
        // a hung server must fail the test, not wedge the harness
        writer
            .set_read_timeout(Some(Duration::from_secs(60)))
            .unwrap();
        let reader = BufReader::new(writer.try_clone().unwrap());
        Client { reader, writer }
    }

    fn send(&mut self, line: &str) {
        writeln!(self.writer, "{line}").unwrap();
        self.writer.flush().unwrap();
    }

    fn recv(&mut self) -> Json {
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    }

    fn request(&mut self, line: &str) -> Json {
        self.send(line);
        self.recv()
    }
}

fn ping(id: u64) -> String {
    format!("{{\"v\":1,\"id\":{id},\"op\":\"ping\"}}")
}

fn analyze(id: u64) -> String {
    let spec = Json::parse(TINY_SPEC).unwrap();
    format!("{{\"v\":1,\"id\":{id},\"op\":\"analyze\",\"spec\":{spec}}}")
}

fn sweep(id: u64, fractions: &[f64]) -> String {
    let ps: Vec<String> = fractions
        .iter()
        .map(|f| format!("{{\"kind\":\"fraction\",\"value\":{f}}}"))
        .collect();
    format!(
        "{{\"v\":1,\"id\":{id},\"op\":\"sweep\",\"workflow\":\"video\",\"perturbations\":[{}]}}",
        ps.join(",")
    )
}

/// N client threads each pipeline a mixed request stream; every session
/// must get exactly its own responses, in its own submission order.
#[test]
fn concurrent_sessions_keep_per_session_order() {
    let mut server = Server::new(ServeOpts {
        threads: 4,
        ..ServeOpts::default()
    });
    let addr = server.listen_tcp("127.0.0.1:0").unwrap();

    const SESSIONS: u64 = 4;
    const REQUESTS: u64 = 12;
    let clients: Vec<_> = (0..SESSIONS)
        .map(|s| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                // pipeline the whole stream before reading anything: the
                // server must still answer strictly in submission order
                for i in 0..REQUESTS {
                    let id = s * 100 + i;
                    let line = if i % 2 == 0 { ping(id) } else { analyze(id) };
                    c.send(&line);
                }
                for i in 0..REQUESTS {
                    let resp = c.recv();
                    let id = s * 100 + i;
                    assert_eq!(resp.get("id").as_f64(), Some(id as f64), "{resp:?}");
                    assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
                    if i % 2 == 1 {
                        let mk = resp.get("result").get("makespan").as_f64().unwrap();
                        assert!((mk - 5.0).abs() < 1e-6, "{mk}");
                    }
                }
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }
    server.shutdown();
}

/// Eight sessions firing sweeps simultaneously at a 1-worker / 1-deep
/// queue: admission control must answer with structured `overloaded`
/// errors — never a hang — while the admitted jobs still complete.
#[test]
fn tiny_queue_reports_overloaded_never_hangs() {
    let mut server = Server::new(ServeOpts {
        threads: 1,
        queue_bound: 1,
        ..ServeOpts::default()
    });
    let addr = server.listen_tcp("127.0.0.1:0").unwrap();

    const SESSIONS: usize = 8;
    const ROUNDS: u64 = 3;
    let barrier = Arc::new(Barrier::new(SESSIONS));
    let clients: Vec<_> = (0..SESSIONS)
        .map(|s| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                // connect first, then fire in lockstep so the volleys
                // actually overlap on the 1-deep queue
                barrier.wait();
                let mut ok = 0u32;
                let mut overloaded = 0u32;
                for r in 0..ROUNDS {
                    let id = s as u64 * 10 + r;
                    let resp = c.request(&sweep(id, &[0.25, 0.5, 0.75, 0.93]));
                    assert_eq!(resp.get("id").as_f64(), Some(id as f64), "{resp:?}");
                    if resp.get("ok").as_bool() == Some(true) {
                        ok += 1;
                    } else {
                        let code = resp.get("error").get("code");
                        assert_eq!(code.as_str(), Some("overloaded"), "{resp:?}");
                        overloaded += 1;
                    }
                }
                (ok, overloaded)
            })
        })
        .collect();
    let mut ok = 0;
    let mut overloaded = 0;
    for c in clients {
        let (o, v) = c.join().unwrap();
        ok += o;
        overloaded += v;
    }
    assert_eq!(ok + overloaded, (SESSIONS as u32) * ROUNDS as u32);
    assert!(ok >= 1, "the admitted jobs must complete");
    assert!(
        overloaded >= 1,
        "8 simultaneous sweeps must trip a 1-deep queue"
    );
    server.shutdown();
}

/// A session's cache honors its entry quota: sweeping many distinct
/// configurations evicts instead of growing without bound, and the
/// response's cache stats show it.
#[test]
fn session_cache_is_bounded_by_quota() {
    // quotas are enforced per shard (16 shards), so 16 is the smallest
    // exactly-enforceable entry quota: one resident entry per shard
    let mut server = Server::new(ServeOpts {
        threads: 2,
        session_cache_entries: 16,
        ..ServeOpts::default()
    });
    let addr = server.listen_tcp("127.0.0.1:0").unwrap();
    let mut c = Client::connect(addr);

    let mut evictions = 0.0;
    for round in 0..4u64 {
        let fractions: Vec<f64> = (0..12)
            .map(|i| 0.05 + (round * 12 + i) as f64 * 0.007)
            .collect();
        let resp = c.request(&sweep(round, &fractions));
        assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
        let cache = resp.get("result").get("cache");
        let entries = cache.get("entries").as_f64().unwrap();
        assert!(entries <= 16.0, "quota of 16 exceeded: {entries}");
        assert!(cache.get("bytes").as_f64().unwrap() > 0.0);
        evictions += cache.get("evictions").as_f64().unwrap();
    }
    assert!(evictions > 0.0, "distinct sweeps must evict under the quota");
    server.shutdown();
}
