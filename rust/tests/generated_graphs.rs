//! Generative property/differential tests over the topology generator
//! family (docs/SCALING.md):
//!
//! * the worklist fixpoint is **bit-for-bit** the full re-solve-everything
//!   fixpoint on every generated DAG (5 shapes × 15 seeds ≥ 60 graphs);
//! * analysis invariants hold on every graph (progress monotone, buffered
//!   data nonnegative, cold == warm cache);
//! * generation is byte-identical per seed;
//! * `simplify_budget` respects its reported error bound at 1000 sampled
//!   points on functions materialized by real solves;
//! * an engine run under `SolverOpts::piece_budget` keeps every
//!   materialized input under the cap and reports a finite error bound.

use bottlemod::runtime::cache::AnalysisCache;
use bottlemod::solver::SolverOpts;
use bottlemod::util::Rng;
use bottlemod::workflow::generator::{fingerprint, generate, GeneratorOpts, Topology};
use bottlemod::workflow::{
    analyze_fixpoint, analyze_fixpoint_cached, analyze_fixpoint_full, Workflow, WorkflowAnalysis,
};

const SEEDS_PER_SHAPE: u64 = 15;
const MAX_PASSES: usize = 8;

fn opts_for(topo: Topology, seed: u64) -> GeneratorOpts {
    // 20–60 nodes, jittered widths, a burst/stream mix, and enough residual
    // pool users to force multi-pass fixpoints (the worklist's hard case)
    GeneratorOpts {
        topology: topo,
        width_jitter: 0.2,
        pool_residual_prob: 0.3,
        burst_prob: 0.3,
        ..GeneratorOpts::default()
    }
    .target_nodes(20 + (seed as usize % 5) * 10)
}

fn graph_for(topo: Topology, seed: u64) -> Workflow {
    let mut rng = Rng::new(0xB07_7E0 + seed);
    generate(&mut rng, &opts_for(topo, seed))
}

/// Bitwise equality of two workflow analyses, field by field
/// (`ProcessInputs` has no `PartialEq`, so inputs compare per component).
fn assert_identical(a: &WorkflowAnalysis, b: &WorkflowAnalysis, ctx: &str) {
    assert_eq!(a.analyses, b.analyses, "{ctx}: analyses differ");
    assert_eq!(a.makespan, b.makespan, "{ctx}: makespan differs");
    assert_eq!(a.pool_residuals, b.pool_residuals, "{ctx}: residuals differ");
    assert_eq!(a.events, b.events, "{ctx}: event accounting differs");
    assert_eq!(a.passes, b.passes, "{ctx}: pass count differs");
    assert_eq!(
        a.budget_err.to_bits(),
        b.budget_err.to_bits(),
        "{ctx}: budget_err differs"
    );
    assert_eq!(a.inputs.len(), b.inputs.len(), "{ctx}");
    for (i, (x, y)) in a.inputs.iter().zip(b.inputs.iter()).enumerate() {
        assert_eq!(x.data, y.data, "{ctx}: node {i} data inputs differ");
        assert_eq!(x.resources, y.resources, "{ctx}: node {i} resources differ");
        assert_eq!(
            x.start_time.to_bits(),
            y.start_time.to_bits(),
            "{ctx}: node {i} start differs"
        );
    }
}

/// Tentpole differential: across every topology shape and seed, the
/// worklist scheduler must reproduce the reference fixpoint bit for bit —
/// analyses, materialized inputs, pool residuals, event accounting, passes.
#[test]
fn worklist_fixpoint_is_bit_identical_to_full() {
    let opts = SolverOpts::default();
    let mut multi_pass = 0usize;
    for topo in Topology::ALL {
        for seed in 0..SEEDS_PER_SHAPE {
            let wf = graph_for(topo, seed);
            let ctx = format!("{}/seed {seed} ({} nodes)", topo.name(), wf.nodes.len());
            let fast = analyze_fixpoint(&wf, &opts, MAX_PASSES)
                .unwrap_or_else(|e| panic!("{ctx}: worklist failed: {e}"));
            let full = analyze_fixpoint_full(&wf, &opts, MAX_PASSES)
                .unwrap_or_else(|e| panic!("{ctx}: full fixpoint failed: {e}"));
            assert_identical(&fast, &full, &ctx);
            assert!(fast.makespan.is_some(), "{ctx}: never finishes");
            if fast.passes > 2 {
                multi_pass += 1;
            }
        }
    }
    // the sweep must actually exercise cross-pass reuse, not just confirm
    // single-pass stability
    assert!(
        multi_pass > 0,
        "no generated graph needed a multi-pass fixpoint — sweep too easy"
    );
}

/// Same differential with piece budgeting on: the worklist must replay
/// budgeted inputs, coarsened demands, and per-node error bounds exactly.
#[test]
fn worklist_matches_full_under_piece_budget() {
    let opts = SolverOpts {
        piece_budget: 12,
        piece_budget_err: 1e-6,
        ..SolverOpts::default()
    };
    for topo in [Topology::ScatterGather, Topology::Genomics] {
        for seed in 0..4 {
            let wf = graph_for(topo, seed);
            let ctx = format!("{}/seed {seed} budgeted", topo.name());
            let fast = analyze_fixpoint(&wf, &opts, MAX_PASSES).unwrap();
            let full = analyze_fixpoint_full(&wf, &opts, MAX_PASSES).unwrap();
            assert_identical(&fast, &full, &ctx);
        }
    }
}

/// Analysis invariants on every generated graph: progress functions are
/// nondecreasing, no consumer ever reads bytes its producer has not yet
/// provided (buffered data ≥ 0), and a cached run is bit-identical cold
/// vs warm.
#[test]
fn generated_graph_invariants() {
    let opts = SolverOpts::default();
    for topo in Topology::ALL {
        for seed in 0..SEEDS_PER_SHAPE {
            let wf = graph_for(topo, seed);
            let ctx = format!("{}/seed {seed}", topo.name());
            let wa = analyze_fixpoint(&wf, &opts, MAX_PASSES).unwrap();
            let horizon = wa.makespan.unwrap_or(1e6) * 1.1 + 1.0;
            for (i, a) in wa.analyses.iter().enumerate() {
                assert!(
                    a.progress.is_nondecreasing(),
                    "{ctx}: node {i} progress decreases"
                );
                let scale = 1.0 + a.max_progress.abs();
                for k in 0..wf.nodes[i].process.data_reqs.len() {
                    for j in 0..25 {
                        let t = a.start_time + (horizon - a.start_time) * j as f64 / 24.0;
                        let provided = wa.inputs[i].data[k].eval(t);
                        let consumed = a.data_consumed_at(&wf.nodes[i].process, k, t);
                        assert!(
                            consumed <= provided + 1e-6 * scale,
                            "{ctx}: node {i} input {k} at t={t}: \
                             consumed {consumed} > provided {provided}"
                        );
                    }
                }
            }

            // cold == warm: a fresh cache changes nothing, and rerunning
            // against the now-populated cache changes nothing either
            let cache = AnalysisCache::new();
            let warm = analyze_fixpoint_cached(&wf, &opts, MAX_PASSES, Some(&cache)).unwrap();
            assert_identical(&wa, &warm, &format!("{ctx}: cold vs warm"));
            let warm2 = analyze_fixpoint_cached(&wf, &opts, MAX_PASSES, Some(&cache)).unwrap();
            assert_identical(&wa, &warm2, &format!("{ctx}: second warm run"));
        }
    }
}

/// Same seed → byte-identical workflow (content fingerprint over every
/// function, wiring edge, and start rule), for every shape and seed.
#[test]
fn same_seed_generation_is_byte_identical() {
    for topo in Topology::ALL {
        for seed in 0..SEEDS_PER_SHAPE {
            let a = fingerprint(&graph_for(topo, seed));
            let b = fingerprint(&graph_for(topo, seed));
            assert_eq!(a, b, "{}/seed {seed} not reproducible", topo.name());
        }
    }
}

/// `simplify_budget` differential: on piecewise functions materialized by
/// real solves, the budgeted approximation stays within the *reported*
/// error bound at 1000 sampled points, and under the piece cap.
#[test]
fn simplify_budget_respects_reported_bound() {
    let opts = SolverOpts::default();
    let mut checked = 0usize;
    for topo in [Topology::ScatterGather, Topology::Genomics, Topology::Layered] {
        for seed in 0..5 {
            let wf = graph_for(topo, seed);
            let wa = analyze_fixpoint(&wf, &opts, MAX_PASSES).unwrap();
            let mut funcs: Vec<&bottlemod::pwfn::PwPoly> = vec![];
            for inp in &wa.inputs {
                funcs.extend(inp.data.iter());
                funcs.extend(inp.resources.iter());
            }
            for a in &wa.analyses {
                funcs.push(&a.progress);
            }
            for f in funcs {
                if f.n_pieces() <= 4 {
                    continue;
                }
                let budget = (f.n_pieces() / 2).max(2);
                let (g, err) = f.simplify_budget(budget, 0.0);
                assert!(g.n_pieces() <= budget, "cap {budget} got {}", g.n_pieces());
                assert!(err.is_finite() && err >= 0.0);
                let lo = if f.x_min().is_finite() { f.x_min() } else { 0.0 };
                let last_finite = f
                    .breaks
                    .iter()
                    .rev()
                    .find(|b| b.is_finite())
                    .copied()
                    .unwrap_or(lo + 1.0);
                let hi = last_finite + 0.1 * (last_finite - lo).abs().max(1.0);
                let mut worst = 0.0f64;
                for j in 0..1000 {
                    let t = lo + (hi - lo) * j as f64 / 999.0;
                    worst = worst.max((g.eval(t) - f.eval(t)).abs());
                }
                let scale = 1.0 + f.eval(hi).abs();
                assert!(
                    worst <= err + 1e-7 * scale,
                    "{}/seed {seed}: sampled error {worst} exceeds reported bound {err}",
                    topo.name()
                );
                checked += 1;
            }
        }
    }
    assert!(
        checked >= 20,
        "only {checked} functions were complex enough to exercise the budget"
    );
}

/// End-to-end piece budgeting on a pool-heavy graph whose residual
/// capacity functions grow far past the cap: every materialized input
/// stays under the budget, the error bound is reported, and the budgeted
/// makespan stays in the same ballpark as the exact one.
#[test]
fn piece_budget_bounds_materialized_inputs() {
    let gopts = GeneratorOpts {
        topology: Topology::ScatterGather,
        width: 30,
        layers: 3,
        pool_residual_prob: 0.6,
        width_jitter: 0.0,
        ..GeneratorOpts::default()
    };
    let mut rng = Rng::new(0xC0FFEE);
    let wf = generate(&mut rng, &gopts);
    assert!(wf.nodes.len() >= 80, "want a wide pool, got {}", wf.nodes.len());

    let exact = analyze_fixpoint(&wf, &SolverOpts::default(), MAX_PASSES).unwrap();
    let peak_exact = exact
        .inputs
        .iter()
        .flat_map(|i| i.data.iter().chain(i.resources.iter()))
        .map(|f| f.n_pieces())
        .max()
        .unwrap();
    assert!(
        peak_exact > 16,
        "exact run only reached {peak_exact} pieces — budget never exercised"
    );

    let bopts = SolverOpts {
        piece_budget: 16,
        piece_budget_err: 1e-6,
        ..SolverOpts::default()
    };
    let budgeted = analyze_fixpoint(&wf, &bopts, MAX_PASSES).unwrap();
    for (i, inp) in budgeted.inputs.iter().enumerate() {
        for f in inp.data.iter().chain(inp.resources.iter()) {
            assert!(
                f.n_pieces() <= 16,
                "node {i}: {} pieces exceed the budget",
                f.n_pieces()
            );
        }
    }
    assert!(
        budgeted.budget_err > 0.0 && budgeted.budget_err.is_finite(),
        "budget never triggered or bound not reported: {}",
        budgeted.budget_err
    );
    let (me, mb) = (exact.makespan.unwrap(), budgeted.makespan.unwrap());
    assert!(
        (me - mb).abs() <= 0.5 * me,
        "budgeted makespan drifted: exact {me} vs budgeted {mb}"
    );
}
