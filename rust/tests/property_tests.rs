//! Property-based tests over randomly generated models (seeded generator;
//! no proptest offline, see `util::harness::check_property`).
//!
//! Invariants checked, each on hundreds of random piecewise-linear models:
//! * the progress function is monotone and never exceeds the data envelope;
//! * Algorithm 2 (exact) and Algorithm 1 (grid) agree on finish times;
//! * the independent fluid executor agrees with the analytic engine on
//!   whole workflows (chains with mixed stream/burst consumers);
//! * relative resource usage stays within [0, 1];
//! * data-progress composition matches pointwise evaluation.

use bottlemod::model::{Process, ProcessBuilder, ProcessInputs};
use bottlemod::pwfn::PwPoly;
use bottlemod::solver::{solve, solve_grid, SolverOpts};
use bottlemod::testbed::fluid::{execute, FluidOpts};
use bottlemod::util::harness::check_property;
use bottlemod::util::Rng;
use bottlemod::workflow::graph::{DataSource, ResourceSource, StartRule, Workflow};

/// Random monotone PL cumulative input over [0, ~100] reaching `total`.
fn random_cumulative(rng: &mut Rng, total: f64) -> PwPoly {
    let n = 1 + rng.below(5);
    let mut points = vec![(0.0, 0.0)];
    for i in 0..n {
        let (x, y) = points[i];
        points.push((
            x + rng.range(2.0, 25.0),
            (y + rng.range(0.0, total * 0.6)).min(total),
        ));
    }
    // ensure it completes
    let (x, y) = *points.last().unwrap();
    if y < total {
        points.push((x + rng.range(2.0, 25.0), total));
    }
    PwPoly::from_points(&points)
}

/// Random single process with 1-2 data inputs and 0-2 stream resources.
fn random_process(rng: &mut Rng) -> (Process, ProcessInputs) {
    let max_p = rng.range(50.0, 200.0);
    let mut b = ProcessBuilder::new("rand", max_p);
    let k = 1 + rng.below(2);
    let mut data = vec![];
    for i in 0..k {
        let total = rng.range(50.0, 300.0);
        if rng.f64() < 0.3 {
            b = b.burst_data(&format!("d{i}"), total);
        } else {
            b = b.stream_data(&format!("d{i}"), total);
        }
        data.push(random_cumulative(rng, total));
    }
    let l = rng.below(3);
    let mut resources = vec![];
    for i in 0..l {
        b = b.stream_resource(&format!("r{i}"), rng.range(10.0, 120.0));
        // piecewise-constant allocation
        let r1 = rng.range(0.2, 3.0);
        let r2 = rng.range(0.2, 3.0);
        let t_switch = rng.range(5.0, 80.0);
        resources.push(PwPoly::step(0.0, t_switch, r1, r2));
    }
    (
        b.identity_output("out").build(),
        ProcessInputs {
            data,
            resources,
            start_time: 0.0,
        },
    )
}

#[test]
fn progress_below_envelope_and_monotone() {
    check_property("P <= P_D, P monotone", 300, |rng| {
        let (p, inputs) = random_process(rng);
        let a = solve(&p, &inputs, &SolverOpts::default())
            .map_err(|e| format!("solve: {e}"))?;
        let tmax = a.finish_time.unwrap_or(500.0) + 10.0;
        let mut prev: f64 = -1e-9;
        for i in 0..200 {
            let t = tmax * i as f64 / 199.0;
            let pv = a.progress.eval(t);
            let pd = a.pd.func.eval(t);
            if pv > pd + 1e-6 * (1.0 + pd.abs()) {
                return Err(format!("P({t})={pv} above envelope {pd}"));
            }
            if pv < prev - 1e-6 * (1.0 + prev.abs()) {
                return Err(format!("P not monotone at t={t}: {prev} -> {pv}"));
            }
            prev = pv;
        }
        Ok(())
    });
}

#[test]
fn exact_agrees_with_grid() {
    check_property("Alg2 == Alg1 (finish times)", 150, |rng| {
        let (p, inputs) = random_process(rng);
        let exact = solve(&p, &inputs, &SolverOpts::default())
            .map_err(|e| format!("solve: {e}"))?;
        let span = exact.finish_time.unwrap_or(500.0) + 20.0;
        let n = 20_000;
        let grid = solve_grid(&p, &inputs, span, n);
        match (exact.finish_time, grid.finish_time) {
            (Some(a), Some(b)) => {
                let dt = span / n as f64;
                if (a - b).abs() > 5.0 * dt + 1e-6 {
                    return Err(format!("finish: exact {a} vs grid {b}"));
                }
            }
            (None, None) => {}
            (a, b) => return Err(format!("finish mismatch: {a:?} vs {b:?}")),
        }
        Ok(())
    });
}

#[test]
fn relative_usage_bounded() {
    check_property("usage in [0,1]", 200, |rng| {
        let (p, inputs) = random_process(rng);
        if p.res_reqs.is_empty() {
            return Ok(());
        }
        let a = solve(&p, &inputs, &SolverOpts::default())
            .map_err(|e| format!("solve: {e}"))?;
        let tmax = a.finish_time.unwrap_or(300.0);
        let ts: Vec<f64> = (0..100).map(|i| tmax * i as f64 / 99.0).collect();
        for l in 0..p.res_reqs.len() {
            for (i, u) in a
                .relative_usage_sampled(&p, &inputs, l, &ts)
                .iter()
                .enumerate()
            {
                if !(-1e-9..=1.0 + 1e-6).contains(u) {
                    return Err(format!("usage[{l}] at t={} is {u}", ts[i]));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn fluid_executor_agrees_on_random_chains() {
    check_property("fluid == analytic on chains", 60, |rng| {
        // producer (stream) -> consumer (stream or burst)
        let total = rng.range(50.0, 150.0);
        let rate = rng.range(1.0, 8.0);
        let mut wf = Workflow::new();
        let prod = ProcessBuilder::new("prod", total)
            .stream_data("src", total)
            .stream_resource("net", total)
            .identity_output("out")
            .build();
        let a = wf.add_node(
            prod,
            vec![DataSource::External(PwPoly::constant(total))],
            vec![ResourceSource::Fixed(PwPoly::constant(rate))],
            StartRule::default(),
        );
        let burst = rng.f64() < 0.5;
        let cpu_total = rng.range(5.0, 60.0);
        let cons = if burst {
            ProcessBuilder::new("cons", total).burst_data("in", total)
        } else {
            ProcessBuilder::new("cons", total).stream_data("in", total)
        }
        .stream_resource("cpu", cpu_total)
        .identity_output("out")
        .build();
        wf.add_node(
            cons,
            vec![DataSource::ProcessOutput { node: a, output: 0 }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );
        let wa = bottlemod::workflow::engine::analyze(&wf, &SolverOpts::default())
            .map_err(|e| format!("analyze: {e}"))?;
        let predicted = wa.makespan.ok_or("no makespan")?;
        let run = execute(
            &wf,
            &FluidOpts {
                dt: 0.01,
                horizon: predicted * 3.0 + 50.0,
                ..FluidOpts::default()
            },
        );
        let measured = run.makespan.ok_or("fluid never finished")?;
        if (predicted - measured).abs() > 0.01 * predicted + 0.1 {
            return Err(format!("predicted {predicted} vs fluid {measured}"));
        }
        Ok(())
    });
}

#[test]
fn data_progress_composition_pointwise() {
    check_property("R(I(t)) composition", 200, |rng| {
        let total = rng.range(20.0, 200.0);
        let input = random_cumulative(rng, total);
        let max_p = rng.range(10.0, 100.0);
        let req = PwPoly::ramp_to(0.0, max_p / total, max_p);
        let composed = req.compose(&input);
        for i in 0..50 {
            let t = 120.0 * i as f64 / 49.0;
            let want = req.eval(input.eval(t));
            let got = composed.eval(t);
            if (want - got).abs() > 1e-6 * (1.0 + want.abs()) {
                return Err(format!("at t={t}: compose {got} vs pointwise {want}"));
            }
        }
        Ok(())
    });
}

#[test]
fn exact_pl_envelope_matches_f64() {
    use bottlemod::pwfn::{PwLinear, Rat};
    check_property("exact PL min == f64 min", 200, |rng| {
        // two random rational lines with small integer coefficients
        let mut mk = |rng: &mut Rng| {
            let y0 = rng.below(20) as i64;
            let num = rng.below(9) as i64 + 1;
            let den = rng.below(9) as i64 + 1;
            (
                PwLinear::linear(
                    Rat::ZERO,
                    Rat::int(y0),
                    Rat::new(num as i128, den as i128).unwrap(),
                ),
                PwPoly::linear_from(0.0, y0 as f64, num as f64 / den as f64),
            )
        };
        let (ea, fa) = mk(rng);
        let (eb, fb) = mk(rng);
        let exact = PwLinear::min_envelope(&[&ea, &eb]).map_err(|e| e.to_string())?;
        let approx = PwPoly::min(&[&fa, &fb]);
        for i in 0..40 {
            let x = i as f64;
            let want = approx.eval(x);
            let got = exact
                .func
                .eval(Rat::from_f64(x).unwrap())
                .map_err(|e| e.to_string())?
                .to_f64();
            if (want - got).abs() > 1e-9 * (1.0 + want.abs()) {
                return Err(format!("at x={x}: exact {got} vs f64 {want}"));
            }
        }
        Ok(())
    });
}
