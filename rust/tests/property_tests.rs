//! Property-based tests over randomly generated models (seeded generator;
//! no proptest offline, see `util::harness::check_property`).
//!
//! Invariants checked, each on hundreds of random piecewise-linear models:
//! * the progress function is monotone and never exceeds the data envelope;
//! * Algorithm 2 (exact) and Algorithm 1 (grid) agree on finish times;
//! * the independent fluid executor agrees with the analytic engine on
//!   whole workflows (chains with mixed stream/burst consumers);
//! * relative resource usage stays within [0, 1];
//! * data-progress composition matches pointwise evaluation.

use bottlemod::model::{Process, ProcessBuilder, ProcessInputs};
use bottlemod::pwfn::PwPoly;
use bottlemod::solver::{solve, solve_grid, SolverOpts};
use bottlemod::testbed::fluid::{execute, FluidOpts};
use bottlemod::util::harness::check_property;
use bottlemod::util::Rng;
use bottlemod::workflow::graph::{DataSource, ResourceSource, StartRule, Workflow};

/// Random monotone PL cumulative input over [0, ~100] reaching `total`.
fn random_cumulative(rng: &mut Rng, total: f64) -> PwPoly {
    let n = 1 + rng.below(5);
    let mut points = vec![(0.0, 0.0)];
    for i in 0..n {
        let (x, y) = points[i];
        points.push((
            x + rng.range(2.0, 25.0),
            (y + rng.range(0.0, total * 0.6)).min(total),
        ));
    }
    // ensure it completes
    let (x, y) = *points.last().unwrap();
    if y < total {
        points.push((x + rng.range(2.0, 25.0), total));
    }
    PwPoly::from_points(&points)
}

/// Random single process with 1-2 data inputs and 0-2 stream resources.
fn random_process(rng: &mut Rng) -> (Process, ProcessInputs) {
    let max_p = rng.range(50.0, 200.0);
    let mut b = ProcessBuilder::new("rand", max_p);
    let k = 1 + rng.below(2);
    let mut data = vec![];
    for i in 0..k {
        let total = rng.range(50.0, 300.0);
        if rng.f64() < 0.3 {
            b = b.burst_data(&format!("d{i}"), total);
        } else {
            b = b.stream_data(&format!("d{i}"), total);
        }
        data.push(random_cumulative(rng, total));
    }
    let l = rng.below(3);
    let mut resources = vec![];
    for i in 0..l {
        b = b.stream_resource(&format!("r{i}"), rng.range(10.0, 120.0));
        // piecewise-constant allocation
        let r1 = rng.range(0.2, 3.0);
        let r2 = rng.range(0.2, 3.0);
        let t_switch = rng.range(5.0, 80.0);
        resources.push(PwPoly::step(0.0, t_switch, r1, r2));
    }
    (
        b.identity_output("out").build(),
        ProcessInputs {
            data,
            resources,
            start_time: 0.0,
        },
    )
}

#[test]
fn progress_below_envelope_and_monotone() {
    check_property("P <= P_D, P monotone", 300, |rng| {
        let (p, inputs) = random_process(rng);
        let a = solve(&p, &inputs, &SolverOpts::default())
            .map_err(|e| format!("solve: {e}"))?;
        let tmax = a.finish_time.unwrap_or(500.0) + 10.0;
        let mut prev: f64 = -1e-9;
        for i in 0..200 {
            let t = tmax * i as f64 / 199.0;
            let pv = a.progress.eval(t);
            let pd = a.pd.func.eval(t);
            if pv > pd + 1e-6 * (1.0 + pd.abs()) {
                return Err(format!("P({t})={pv} above envelope {pd}"));
            }
            if pv < prev - 1e-6 * (1.0 + prev.abs()) {
                return Err(format!("P not monotone at t={t}: {prev} -> {pv}"));
            }
            prev = pv;
        }
        Ok(())
    });
}

#[test]
fn exact_agrees_with_grid() {
    check_property("Alg2 == Alg1 (finish times)", 150, |rng| {
        let (p, inputs) = random_process(rng);
        let exact = solve(&p, &inputs, &SolverOpts::default())
            .map_err(|e| format!("solve: {e}"))?;
        let span = exact.finish_time.unwrap_or(500.0) + 20.0;
        let n = 20_000;
        let grid = solve_grid(&p, &inputs, span, n);
        match (exact.finish_time, grid.finish_time) {
            (Some(a), Some(b)) => {
                let dt = span / n as f64;
                if (a - b).abs() > 5.0 * dt + 1e-6 {
                    return Err(format!("finish: exact {a} vs grid {b}"));
                }
            }
            (None, None) => {}
            (a, b) => return Err(format!("finish mismatch: {a:?} vs {b:?}")),
        }
        Ok(())
    });
}

#[test]
fn relative_usage_bounded() {
    check_property("usage in [0,1]", 200, |rng| {
        let (p, inputs) = random_process(rng);
        if p.res_reqs.is_empty() {
            return Ok(());
        }
        let a = solve(&p, &inputs, &SolverOpts::default())
            .map_err(|e| format!("solve: {e}"))?;
        let tmax = a.finish_time.unwrap_or(300.0);
        let ts: Vec<f64> = (0..100).map(|i| tmax * i as f64 / 99.0).collect();
        for l in 0..p.res_reqs.len() {
            for (i, u) in a
                .relative_usage_sampled(&p, &inputs, l, &ts)
                .iter()
                .enumerate()
            {
                if !(-1e-9..=1.0 + 1e-6).contains(u) {
                    return Err(format!("usage[{l}] at t={} is {u}", ts[i]));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn fluid_executor_agrees_on_random_chains() {
    check_property("fluid == analytic on chains", 60, |rng| {
        // producer (stream) -> consumer (stream or burst)
        let total = rng.range(50.0, 150.0);
        let rate = rng.range(1.0, 8.0);
        let mut wf = Workflow::new();
        let prod = ProcessBuilder::new("prod", total)
            .stream_data("src", total)
            .stream_resource("net", total)
            .identity_output("out")
            .build();
        let a = wf.add_node(
            prod,
            vec![DataSource::External(PwPoly::constant(total))],
            vec![ResourceSource::Fixed(PwPoly::constant(rate))],
            StartRule::default(),
        );
        let burst = rng.f64() < 0.5;
        let cpu_total = rng.range(5.0, 60.0);
        let cons = if burst {
            ProcessBuilder::new("cons", total).burst_data("in", total)
        } else {
            ProcessBuilder::new("cons", total).stream_data("in", total)
        }
        .stream_resource("cpu", cpu_total)
        .identity_output("out")
        .build();
        wf.add_node(
            cons,
            vec![DataSource::ProcessOutput { node: a, output: 0 }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );
        let wa = bottlemod::workflow::engine::analyze(&wf, &SolverOpts::default())
            .map_err(|e| format!("analyze: {e}"))?;
        let predicted = wa.makespan.ok_or("no makespan")?;
        let run = execute(
            &wf,
            &FluidOpts {
                dt: 0.01,
                horizon: predicted * 3.0 + 50.0,
                ..FluidOpts::default()
            },
        );
        let measured = run.makespan.ok_or("fluid never finished")?;
        if (predicted - measured).abs() > 0.01 * predicted + 0.1 {
            return Err(format!("predicted {predicted} vs fluid {measured}"));
        }
        Ok(())
    });
}

#[test]
fn data_progress_composition_pointwise() {
    check_property("R(I(t)) composition", 200, |rng| {
        let total = rng.range(20.0, 200.0);
        let input = random_cumulative(rng, total);
        let max_p = rng.range(10.0, 100.0);
        let req = PwPoly::ramp_to(0.0, max_p / total, max_p);
        let composed = req.compose(&input);
        for i in 0..50 {
            let t = 120.0 * i as f64 / 49.0;
            let want = req.eval(input.eval(t));
            let got = composed.eval(t);
            if (want - got).abs() > 1e-6 * (1.0 + want.abs()) {
                return Err(format!("at t={t}: compose {got} vs pointwise {want}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// pwfn algebra invariants on randomized piecewise inputs
// ---------------------------------------------------------------------------

/// Random piecewise polynomial (degree ≤ 2) with an infinite tail.
fn random_pw(rng: &mut Rng) -> bottlemod::pwfn::PwPoly {
    use bottlemod::pwfn::{poly::Poly, PwPoly};
    let pieces = 1 + rng.below(5);
    let mut breaks = vec![rng.range(-2.0, 2.0)];
    for i in 0..pieces - 1 {
        let prev = breaks[i];
        breaks.push(prev + rng.range(0.5, 8.0));
    }
    breaks.push(f64::INFINITY);
    let polys = (0..pieces)
        .map(|_| {
            let deg = rng.below(3);
            Poly::new((0..=deg).map(|_| rng.range(-3.0, 3.0)).collect())
        })
        .collect();
    PwPoly::new(breaks, polys)
}

/// Sample points covering the function's breaks and the gaps between them,
/// avoiding exact breakpoints (where right-continuity vs left limits would
/// make pointwise comparisons ambiguous).
fn sample_points(rng: &mut Rng, f: &bottlemod::pwfn::PwPoly, n: usize) -> Vec<f64> {
    let lo = f.x_min() - 3.0;
    let hi = f
        .breaks
        .iter()
        .filter(|b| b.is_finite())
        .fold(f.x_min(), |m, &b| m.max(b))
        + 10.0;
    (0..n).map(|_| rng.range(lo, hi)).collect()
}

/// Strictly increasing piecewise-linear function through random points.
fn random_increasing_pl(rng: &mut Rng) -> (bottlemod::pwfn::PwPoly, f64, f64) {
    let n = 2 + rng.below(5);
    let mut points = vec![(rng.range(-1.0, 1.0), rng.range(-1.0, 1.0))];
    for i in 0..n {
        let (x, y) = points[i];
        points.push((x + rng.range(0.5, 5.0), y + rng.range(0.5, 5.0)));
    }
    let f = bottlemod::pwfn::PwPoly::from_points(&points);
    // exclude the trailing constant extension: the invertible span is
    // [first x, last x) in x and [first y, last y) in y
    let last = points[points.len() - 1];
    (f, points[0].0, last.0)
}

#[test]
fn add_mul_closed_under_refinement() {
    check_property("add/mul == pointwise, stable under refine", 300, |rng| {
        let f = random_pw(rng);
        let g = random_pw(rng);
        let sum = f.add(&g);
        let prod = f.mul(&g);
        // refining with arbitrary extra cuts must not change either result
        let cuts: Vec<f64> = (0..4).map(|_| rng.range(-5.0, 40.0)).collect();
        let sum_r = sum.refine(&cuts);
        let prod_r = prod.refine(&cuts);
        for &x in &sample_points(rng, &sum, 60) {
            let want_sum = f.eval(x) + g.eval(x);
            let want_prod = f.eval(x) * g.eval(x);
            for (got, want, what) in [
                (sum.eval(x), want_sum, "add"),
                (sum_r.eval(x), want_sum, "add+refine"),
                (prod.eval(x), want_prod, "mul"),
                (prod_r.eval(x), want_prod, "mul+refine"),
            ] {
                if (got - want).abs() > 1e-6 * (1.0 + want.abs()) {
                    return Err(format!("{what} at x={x}: {got} vs {want}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn min_envelope_below_inputs_with_correct_winners() {
    use bottlemod::pwfn::PwPoly;
    check_property("envelope <= all inputs, winner attains it", 300, |rng| {
        let fns: Vec<PwPoly> = (0..3).map(|_| random_pw(rng)).collect();
        let refs: Vec<&PwPoly> = fns.iter().collect();
        let env = PwPoly::min_envelope(&refs);
        for &x in &sample_points(rng, &env.func, 80) {
            let ev = env.func.eval(x);
            let min_v = fns.iter().map(|f| f.eval(x)).fold(f64::INFINITY, f64::min);
            let tol = 1e-6 * (1.0 + min_v.abs());
            // lower envelope: matches the pointwise minimum
            if (ev - min_v).abs() > tol {
                return Err(format!("env({x})={ev} but min={min_v}"));
            }
            // attribution: the claimed winner attains the envelope value
            let w = env.winner_at(x);
            if w >= fns.len() {
                return Err(format!("winner index {w} out of range at x={x}"));
            }
            let wv = fns[w].eval(x);
            if (wv - ev).abs() > tol {
                return Err(format!(
                    "winner {w} at x={x} has value {wv}, envelope {ev}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn compose_inverse_linear_roundtrip() {
    check_property("f(f^-1(y)) == y and f^-1(f(x)) == x", 300, |rng| {
        let (f, x0, x1) = random_increasing_pl(rng);
        let inv = f.inverse_linear().map_err(|e| e.to_string())?;
        let (y0, y1) = (f.eval(x0), f.eval_left(x1));
        for _ in 0..40 {
            let y = rng.range(y0, y1 - 1e-9);
            let x = inv.eval(y);
            let back = f.eval(x);
            if (back - y).abs() > 1e-6 * (1.0 + y.abs()) {
                return Err(format!("f(inv({y})) = {back}"));
            }
            let x_direct = rng.range(x0, x1 - 1e-9);
            let roundtrip = inv.eval(f.eval(x_direct));
            if (roundtrip - x_direct).abs() > 1e-6 * (1.0 + x_direct.abs()) {
                return Err(format!("inv(f({x_direct})) = {roundtrip}"));
            }
        }
        // compose-based check: inv ∘ f is the identity on the span
        let ident = inv.compose(&f);
        for _ in 0..20 {
            let x = rng.range(x0, x1 - 1e-9);
            let got = ident.eval(x);
            if (got - x).abs() > 1e-6 * (1.0 + x.abs()) {
                return Err(format!("(inv∘f)({x}) = {got}"));
            }
        }
        Ok(())
    });
}

#[test]
fn antiderivative_derivative_identity() {
    check_property("d/dx ∫f == f", 300, |rng| {
        let f = random_pw(rng);
        let c0 = rng.range(-5.0, 5.0);
        let g = f.antiderivative(c0).derivative();
        for &x in &sample_points(rng, &f, 60) {
            let want = f.eval(x);
            let got = g.eval(x);
            if (got - want).abs() > 1e-6 * (1.0 + want.abs()) {
                return Err(format!("at x={x}: {got} vs {want}"));
            }
        }
        // and the antiderivative anchors at c0
        let a = f.antiderivative(c0);
        if (a.eval(f.x_min()) - c0).abs() > 1e-9 * (1.0 + c0.abs()) {
            return Err(format!("F(x_min) = {} != {c0}", a.eval(f.x_min())));
        }
        Ok(())
    });
}

#[test]
fn exact_pl_envelope_matches_f64() {
    use bottlemod::pwfn::{PwLinear, Rat};
    check_property("exact PL min == f64 min", 200, |rng| {
        // two random rational lines with small integer coefficients
        let mut mk = |rng: &mut Rng| {
            let y0 = rng.below(20) as i64;
            let num = rng.below(9) as i64 + 1;
            let den = rng.below(9) as i64 + 1;
            (
                PwLinear::linear(
                    Rat::ZERO,
                    Rat::int(y0),
                    Rat::new(num as i128, den as i128).unwrap(),
                ),
                PwPoly::linear_from(0.0, y0 as f64, num as f64 / den as f64),
            )
        };
        let (ea, fa) = mk(rng);
        let (eb, fb) = mk(rng);
        let exact = PwLinear::min_envelope(&[&ea, &eb]).map_err(|e| e.to_string())?;
        let approx = PwPoly::min(&[&fa, &fb]);
        for i in 0..40 {
            let x = i as f64;
            let want = approx.eval(x);
            let got = exact
                .func
                .eval(Rat::from_f64(x).unwrap())
                .map_err(|e| e.to_string())?
                .to_f64();
            if (want - got).abs() > 1e-9 * (1.0 + want.abs()) {
                return Err(format!("at x={x}: exact {got} vs f64 {want}"));
            }
        }
        Ok(())
    });
}
