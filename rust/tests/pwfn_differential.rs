//! Differential property tests pinning the pre-refactor pwfn semantics.
//!
//! The allocation-lean kernel (streaming two-sequence merge, k-way
//! `sum_all`/`min_all`/`max_all`, in-place ops) must not change results:
//!
//! * **bit-for-bit** where the computation is reordering-free — the
//!   streaming binary `add`/`sub`/`mul` against a verbatim copy of the old
//!   `common_breaks` + `local_poly_at` implementation, the in-place ops
//!   against their pure counterparts, `refine`/`clip` fast paths against
//!   the identity;
//! * **≤ 1e-9 relative** where accumulation order changes (`sum_all` vs
//!   the sequential pairwise fold) — near-coincident breakpoints may keep
//!   a different `EPS_BREAK`-cluster representative, and `x + 0.0` vs `x`
//!   flips the sign of exact zeros;
//! * **≤ 1e-6 relative** for the k-way envelope against the retained
//!   pairwise reference (`min_envelope_pairwise`) — crossing placement is
//!   root-finding, so the two agree to root tolerance (the historical
//!   envelope property-test tolerance), and every claimed winner must
//!   attain the envelope.
//!
//! Inputs cover step discontinuities, constant and single-piece functions,
//! finite domains (constant extension), differing domain starts, and
//! near-coincident breakpoints.

use bottlemod::pwfn::{break_tol, poly::Poly, PwPoly};
use bottlemod::util::harness::check_property;
use bottlemod::util::Rng;

// ------------------------------------------------------------- generators

/// Random piecewise polynomial: degree ≤ 2 pieces with jumps, 20% constant
/// pieces, 25% finite domains, random domain start.
fn random_pw(rng: &mut Rng) -> PwPoly {
    let pieces = 1 + rng.below(6);
    let mut breaks = vec![rng.range(-3.0, 3.0)];
    for i in 0..pieces - 1 {
        let prev = breaks[i];
        breaks.push(prev + rng.range(0.5, 6.0));
    }
    if rng.f64() < 0.25 {
        let prev = *breaks.last().unwrap();
        breaks.push(prev + rng.range(0.5, 6.0));
    } else {
        breaks.push(f64::INFINITY);
    }
    let polys = (0..pieces)
        .map(|_| {
            if rng.f64() < 0.2 {
                Poly::constant(rng.range(-4.0, 4.0))
            } else {
                let deg = rng.below(3);
                Poly::new((0..=deg).map(|_| rng.range(-3.0, 3.0)).collect())
            }
        })
        .collect();
    PwPoly::new(breaks, polys)
}

/// A function sharing `f`'s break skeleton, each finite break perturbed
/// *upward* by a sub-[`break_tol`] offset — the near-coincident dedup
/// stressor. (Upward so the perturbed break dedups against the original:
/// the kernel — old and new alike — only collapses a cut against the
/// preceding break.)
fn near_coincident_variant(rng: &mut Rng, f: &PwPoly) -> PwPoly {
    let breaks: Vec<f64> = f
        .breaks
        .iter()
        .map(|&b| {
            if b.is_finite() {
                b + 0.3 * break_tol(b, b) * rng.f64()
            } else {
                b
            }
        })
        .collect();
    let polys = f
        .polys
        .iter()
        .map(|_| Poly::new((0..=rng.below(2)).map(|_| rng.range(-3.0, 3.0)).collect()))
        .collect();
    PwPoly::new(breaks, polys)
}

/// Sample points spanning both functions' finite spans (plus margins),
/// random so exact breakpoints are hit with probability 0.
fn sample_xs(rng: &mut Rng, fns: &[&PwPoly], n: usize) -> Vec<f64> {
    let lo = fns.iter().map(|f| f.x_min()).fold(f64::INFINITY, f64::min) - 3.0;
    let hi = fns
        .iter()
        .flat_map(|f| f.breaks.iter())
        .copied()
        .filter(|b| b.is_finite())
        .fold(lo, f64::max)
        + 10.0;
    (0..n).map(|_| rng.range(lo, hi)).collect()
}

// --------------------------------------------------- reference (PR 3) code

/// Verbatim copy of the pre-refactor `common_breaks` (sorted union,
/// `dedup_by` to the same tolerance).
fn ref_common_breaks(f: &PwPoly, g: &PwPoly) -> Vec<f64> {
    let lo = f.breaks[0].min(g.breaks[0]);
    let hi = f.x_max().max(g.x_max());
    let mut all: Vec<f64> = f
        .breaks
        .iter()
        .chain(g.breaks.iter())
        .copied()
        .filter(|x| x.is_finite())
        .collect();
    all.push(lo);
    all.sort_by(|a, b| a.partial_cmp(b).unwrap());
    all.dedup_by(|a, b| (*a - *b).abs() < break_tol(*a, *b));
    if hi.is_infinite() {
        all.push(f64::INFINITY);
    }
    all
}

/// Verbatim copy of the pre-refactor `zip_with` (per-interval
/// `local_poly_at`, i.e. a binary search + shift per operand per piece).
fn ref_zip(f: &PwPoly, g: &PwPoly, op: impl Fn(&Poly, &Poly) -> Poly) -> PwPoly {
    let breaks = ref_common_breaks(f, g);
    let mut polys = Vec::with_capacity(breaks.len() - 1);
    for i in 0..breaks.len() - 1 {
        let s = breaks[i];
        polys.push(op(&f.local_poly_at(s), &g.local_poly_at(s)));
    }
    PwPoly::new(breaks, polys)
}

// ------------------------------------------------------------------- tests

#[test]
fn streaming_binary_ops_bitwise_match_reference() {
    check_property("add/sub/mul == PR3 reference, bitwise", 400, |rng| {
        let f = random_pw(rng);
        let g = if rng.f64() < 0.3 {
            near_coincident_variant(rng, &f)
        } else {
            random_pw(rng)
        };
        for (name, got, want) in [
            ("add", f.add(&g), ref_zip(&f, &g, |a, b| a.add(b))),
            ("sub", f.sub(&g), ref_zip(&f, &g, |a, b| a.sub(b))),
            ("mul", f.mul(&g), ref_zip(&f, &g, |a, b| a.mul(b))),
        ] {
            if got != want {
                return Err(format!(
                    "{name} diverged from reference:\n got {got:?}\nwant {want:?}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn sum_all_matches_sequential_fold() {
    check_property("sum_all == pairwise fold (<= 1e-9 rel)", 300, |rng| {
        let k = 1 + rng.below(5);
        let mut fns: Vec<PwPoly> = (0..k).map(|_| random_pw(rng)).collect();
        if k >= 2 && rng.f64() < 0.3 {
            let v = near_coincident_variant(rng, &fns[0]);
            fns[1] = v;
        }
        let refs: Vec<&PwPoly> = fns.iter().collect();
        let kway = PwPoly::sum_all(&refs);
        let fold = fns[1..]
            .iter()
            .fold(fns[0].clone(), |acc, f| acc.add(f));
        for &x in &sample_xs(rng, &refs, 60) {
            let (a, b) = (kway.eval(x), fold.eval(x));
            if (a - b).abs() > 1e-9 * (1.0 + b.abs()) {
                return Err(format!("sum_all({x}) = {a} vs fold {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn kway_envelope_matches_pairwise_reference() {
    check_property("min_envelope == pairwise (<= 1e-6 rel)", 300, |rng| {
        let k = 2 + rng.below(4);
        let fns: Vec<PwPoly> = (0..k).map(|_| random_pw(rng)).collect();
        // single input: the fast path must be bitwise the pairwise output
        // (the reference dedups even a lone function)
        let lone = PwPoly::min_envelope(&[&fns[0]]);
        let lone_ref = PwPoly::min_envelope_pairwise(&[&fns[0]]);
        if lone != lone_ref {
            return Err(format!(
                "k=1 envelope diverged:\n got {lone:?}\nwant {lone_ref:?}"
            ));
        }
        let refs: Vec<&PwPoly> = fns.iter().collect();
        let kway = PwPoly::min_envelope(&refs);
        let pair = PwPoly::min_envelope_pairwise(&refs);
        for &x in &sample_xs(rng, &refs, 80) {
            let (a, b) = (kway.func.eval(x), pair.func.eval(x));
            let tol = 1e-6 * (1.0 + b.abs());
            if (a - b).abs() > tol {
                return Err(format!("envelope({x}) = {a} vs pairwise {b}"));
            }
            // pointwise minimum, both implementations
            let min_v = fns.iter().map(|f| f.eval(x)).fold(f64::INFINITY, f64::min);
            if (a - min_v).abs() > tol {
                return Err(format!("envelope({x}) = {a} but min = {min_v}"));
            }
            // the claimed winner attains the envelope
            let w = kway.winner_at(x);
            if w >= fns.len() {
                return Err(format!("winner {w} out of range at x = {x}"));
            }
            let wv = fns[w].eval(x);
            if (wv - a).abs() > tol {
                return Err(format!("winner {w} at {x} has {wv}, envelope {a}"));
            }
        }
        Ok(())
    });
}

#[test]
fn max_all_matches_max_with_fold() {
    check_property("max_all == max_with fold (<= 1e-6 rel)", 200, |rng| {
        let k = 2 + rng.below(3);
        let fns: Vec<PwPoly> = (0..k).map(|_| random_pw(rng)).collect();
        let refs: Vec<&PwPoly> = fns.iter().collect();
        let kway = PwPoly::max_all(&refs);
        let fold = fns[1..]
            .iter()
            .fold(fns[0].clone(), |acc, f| acc.max_with(f));
        for &x in &sample_xs(rng, &refs, 60) {
            let (a, b) = (kway.eval(x), fold.eval(x));
            if (a - b).abs() > 1e-6 * (1.0 + b.abs()) {
                return Err(format!("max_all({x}) = {a} vs fold {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn in_place_ops_bitwise_match_pure() {
    check_property("in-place == pure, bitwise", 300, |rng| {
        let f = random_pw(rng);
        let g = random_pw(rng);
        // add_assign, general breaks (streaming fallback)
        let mut a = f.clone();
        a.add_assign(&g);
        if a != f.add(&g) {
            return Err("add_assign (general) != add".into());
        }
        // add_assign, shared breaks (true in-place path)
        let same_breaks = PwPoly::new(
            f.breaks.clone(),
            f.polys
                .iter()
                .map(|_| Poly::new((0..=rng.below(3)).map(|_| rng.range(-3.0, 3.0)).collect()))
                .collect(),
        );
        let mut b = f.clone();
        b.add_assign(&same_breaks);
        if b != f.add(&same_breaks) {
            return Err("add_assign (shared breaks) != add".into());
        }
        // scale_mut / shift_x_mut
        let kf = rng.range(-3.0, 3.0);
        let mut c = f.clone();
        c.scale_mut(kf);
        if c != f.scale(kf) {
            return Err(format!("scale_mut({kf}) != scale"));
        }
        let dx = rng.range(-5.0, 5.0);
        let mut d = f.clone();
        d.shift_x_mut(dx);
        if d != f.shift_x(dx) {
            return Err(format!("shift_x_mut({dx}) != shift_x"));
        }
        // refine_in_place, including duplicates and out-of-domain cuts
        let cuts: Vec<f64> = (0..4).map(|_| rng.range(-8.0, 30.0)).collect();
        let mut e = f.clone();
        e.refine_in_place(&cuts);
        if e != f.refine(&cuts) {
            return Err("refine_in_place != refine".into());
        }
        let mut n = f.clone();
        n.refine_in_place(&[]);
        if n != f {
            return Err("refine_in_place(&[]) changed the function".into());
        }
        Ok(())
    });
}

#[test]
fn cheap_paths_are_identities() {
    check_property("refine(&[]) / whole-domain clip identities", 200, |rng| {
        let f = random_pw(rng);
        if f.refine(&[]) != f {
            return Err("refine(&[]) != self".into());
        }
        if f.clip(f.x_min(), f.x_max()) != f {
            return Err("whole-domain clip != self".into());
        }
        if f.clone().clipped(f.x_min() - 1.0, f.x_max()) != f {
            return Err("clipped (from left of domain) != self".into());
        }
        // a genuine clip agrees between by-ref and by-value
        let last_finite = f
            .breaks
            .iter()
            .copied()
            .filter(|b| b.is_finite())
            .fold(f.x_min(), f64::max);
        let a = f.x_min() + 0.25;
        let b = last_finite + 2.0;
        if b > a && f.clone().clipped(a, b) != f.clip(a, b) {
            return Err("clipped != clip on a real restriction".into());
        }
        Ok(())
    });
}

#[test]
fn near_coincident_breaks_collapse_identically() {
    check_property("EPS_BREAK cluster collapse is op-independent", 200, |rng| {
        let f = random_pw(rng);
        let g = near_coincident_variant(rng, &f);
        // every op sees one break per cluster: binary add (streaming),
        // the PR3 reference, and refine with g's breaks as cuts agree on
        // the merged break count
        let sum = f.add(&g);
        let reference = ref_zip(&f, &g, |a, b| a.add(b));
        if sum.breaks != reference.breaks {
            return Err(format!(
                "streaming vs reference break sets:\n {:?}\nvs {:?}",
                sum.breaks, reference.breaks
            ));
        }
        let finite_cuts: Vec<f64> = g
            .breaks
            .iter()
            .copied()
            .filter(|b| b.is_finite())
            .collect();
        let refined = f.refine(&finite_cuts);
        if refined.breaks.len() != f.breaks.len() {
            return Err(format!(
                "refine added a break inside an EPS_BREAK cluster: {:?} from {:?}",
                refined.breaks, f.breaks
            ));
        }
        Ok(())
    });
}
