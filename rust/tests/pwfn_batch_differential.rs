//! Differential suite pinning the structure-of-arrays batch backend
//! (`pwfn::BatchPwPoly`) to the scalar evaluator, bit for bit.
//!
//! Randomized functions cover jump breaks, infinite and finite final
//! pieces, single-piece constants and mixed degrees (zero-padding in the
//! compiled block); query grids cover x exactly on breakpoints, x just
//! around them, x left of the domain, x past a finite domain end, and
//! both sorted and arbitrary orders. Also pins the structural identity
//! `eval_grid == transpose(eval_scenarios)` and the `PwPoly::sample` /
//! `eval_many` delegation.

use bottlemod::pwfn::{poly::Poly, BatchPwPoly, PwPoly};
use bottlemod::util::harness::check_property;
use bottlemod::util::Rng;

/// Random piecewise polynomial: 1–6 pieces, jumps between pieces, 20%
/// plain constants, 25% finite domain end, degree ≤ 3.
fn random_pw(rng: &mut Rng) -> PwPoly {
    if rng.f64() < 0.2 {
        return PwPoly::constant(rng.range(-5.0, 5.0));
    }
    let pieces = 1 + rng.below(6) as usize;
    let mut breaks = Vec::with_capacity(pieces + 1);
    breaks.push(rng.range(-3.0, 3.0));
    for i in 0..pieces - 1 {
        let prev = breaks[i];
        breaks.push(prev + rng.range(0.25, 2.0));
    }
    if rng.f64() < 0.25 {
        let prev = *breaks.last().unwrap();
        breaks.push(prev + rng.range(0.25, 2.0));
    } else {
        breaks.push(f64::INFINITY);
    }
    let degree = rng.below(4) as usize;
    let polys = (0..pieces)
        .map(|_| Poly::new((0..=degree).map(|_| rng.range(-2.0, 2.0)).collect()))
        .collect();
    PwPoly::new(breaks, polys)
}

/// Query grid hitting every interesting region: left of the domain,
/// exactly on each finite breakpoint, just around each, past the domain
/// end, plus random interior points. Returned in generation order — NOT
/// sorted.
fn sample_xs(rng: &mut Rng, f: &PwPoly) -> Vec<f64> {
    let mut xs = vec![f.x_min() - rng.range(0.5, 3.0)];
    for &b in &f.breaks {
        if b.is_finite() {
            xs.push(b);
            xs.push(b - 1e-9);
            xs.push(b + 1e-9);
        }
    }
    let hi = if f.x_max().is_finite() {
        f.x_max() + 3.0
    } else {
        f.x_min() + 15.0
    };
    for _ in 0..24 {
        xs.push(rng.range(f.x_min() - 1.0, hi));
    }
    xs
}

fn assert_bits(got: &[f64], f: &PwPoly, xs: &[f64], what: &str) -> Result<(), String> {
    for (&x, &v) in xs.iter().zip(got) {
        let want = f.eval(x);
        if v.to_bits() != want.to_bits() {
            return Err(format!("{what}: f({x}) = {v:?}, scalar says {want:?}"));
        }
    }
    Ok(())
}

#[test]
fn eval_many_matches_scalar_any_order() {
    check_property("eval_many == scalar eval", 300, |rng| {
        let f = random_pw(rng);
        let xs = sample_xs(rng, &f); // unsorted generation order
        let b = BatchPwPoly::compile_one(&f);
        assert_bits(&b.eval_many(&xs), &f, &xs, "eval_many (unsorted)")?;
        let mut sorted = xs.clone();
        sorted.sort_by(f64::total_cmp);
        assert_bits(&b.eval_many(&sorted), &f, &sorted, "eval_many (sorted)")?;
        assert_bits(&b.eval_many_sorted(&sorted), &f, &sorted, "eval_many_sorted")?;
        // reverse order exercises the backward gallop
        let mut rev = sorted.clone();
        rev.reverse();
        assert_bits(&b.eval_many(&rev), &f, &rev, "eval_many (reversed)")?;
        Ok(())
    });
}

#[test]
fn pwpoly_methods_delegate_to_batch() {
    check_property("sample/eval_many delegation", 100, |rng| {
        let f = random_pw(rng);
        let xs = sample_xs(rng, &f);
        assert_bits(&f.eval_many(&xs), &f, &xs, "PwPoly::eval_many")?;
        assert_bits(&f.sample(&xs), &f, &xs, "PwPoly::sample")?;
        let mut sorted = xs;
        sorted.sort_by(f64::total_cmp);
        assert_bits(&f.eval_many_sorted(&sorted), &f, &sorted, "PwPoly::eval_many_sorted")?;
        Ok(())
    });
}

#[test]
fn grid_is_transposed_scenarios_and_both_match_scalar() {
    check_property("eval_grid == transpose(eval_scenarios)", 200, |rng| {
        let m = 1 + rng.below(5) as usize;
        let fns: Vec<PwPoly> = (0..m).map(|_| random_pw(rng)).collect();
        let refs: Vec<&PwPoly> = fns.iter().collect();
        // one shared grid spanning all domains, sorted half the time
        let lo = fns.iter().map(|f| f.x_min()).fold(f64::INFINITY, f64::min);
        let mut xs: Vec<f64> = (0..40).map(|_| rng.range(lo - 2.0, lo + 15.0)).collect();
        if rng.f64() < 0.5 {
            xs.sort_by(f64::total_cmp);
        }
        let b = BatchPwPoly::compile(&refs);
        let scen = b.eval_scenarios(&xs);
        let grid = b.eval_grid(&xs);
        if scen.len() != m * xs.len() || grid.len() != m * xs.len() {
            return Err(format!(
                "bad shapes: scen {} grid {} want {}",
                scen.len(),
                grid.len(),
                m * xs.len()
            ));
        }
        for (i, f) in fns.iter().enumerate() {
            for (j, &x) in xs.iter().enumerate() {
                let s = scen[i * xs.len() + j];
                let g = grid[j * m + i];
                if s.to_bits() != g.to_bits() {
                    return Err(format!("transpose mismatch at fn {i}, point {j}"));
                }
                let want = f.eval(x);
                if s.to_bits() != want.to_bits() {
                    return Err(format!("scenarios vs scalar at fn {i}, x={x}: {s:?} vs {want:?}"));
                }
                // eval_one is the per-point reference entry
                let one = b.eval_one(i, x);
                if one.to_bits() != want.to_bits() {
                    return Err(format!("eval_one vs scalar at fn {i}, x={x}"));
                }
            }
        }
        Ok(())
    });
}

/// Deterministic edge geometry: single pieces, jump steps, finite domain
/// ends, empty compiles and empty grids.
#[test]
fn edge_cases_exact() {
    // single-piece constant: every x lands on piece 0
    let c = PwPoly::constant(42.0);
    let b = BatchPwPoly::compile_one(&c);
    for x in [-1e9, -1.0, 0.0, 7.5, 1e12] {
        assert_eq!(b.eval_one(0, x).to_bits(), c.eval(x).to_bits());
    }

    // jump step: right-continuity exactly at the break
    let s = PwPoly::step(0.0, 10.0, 1.0, 5.0);
    let bs = BatchPwPoly::compile_one(&s);
    let xs = [9.999999999, 10.0, 10.000000001];
    for (&x, &v) in xs.iter().zip(&bs.eval_many(&xs)) {
        assert_eq!(v.to_bits(), s.eval(x).to_bits(), "x={x}");
    }

    // finite domain end: constant extension past x_max
    let fin = PwPoly::new(
        vec![0.0, 1.0, 2.0],
        vec![Poly::linear(0.0, 1.0), Poly::linear(1.0, 2.0)],
    );
    let bf = BatchPwPoly::compile_one(&fin);
    for x in [1.5, 2.0, 3.0, 100.0] {
        assert_eq!(bf.eval_one(0, x).to_bits(), fin.eval(x).to_bits(), "x={x}");
    }

    // empty function list and empty grids
    let none = BatchPwPoly::compile(&[]);
    assert_eq!(none.n_funcs(), 0);
    assert!(none.eval_scenarios(&[1.0]).is_empty());
    assert!(none.eval_grid(&[1.0]).is_empty());
    assert!(b.eval_many(&[]).is_empty());
    assert!(b.eval_many_sorted(&[]).is_empty());

    // mixed degrees in one compile: zero-padding must not perturb values
    let quad = PwPoly::new(vec![0.0, f64::INFINITY], vec![Poly::new(vec![1.0, -2.0, 0.5])]);
    let both = BatchPwPoly::compile(&[&c, &quad]);
    assert_eq!(both.coeff_width(), 3);
    for x in [-1.0, 0.0, 2.25, 50.0] {
        assert_eq!(both.eval_one(0, x).to_bits(), c.eval(x).to_bits());
        assert_eq!(both.eval_one(1, x).to_bits(), quad.eval(x).to_bits());
    }
}
