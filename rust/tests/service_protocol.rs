//! Golden tests for the wire protocol (`docs/SERVICE.md`).
//!
//! The table-driven half pins *exact* response bytes for the
//! protocol-shape cases — valid v1, legacy v0, malformed JSON, unknown
//! ops, wrong-typed fields — relying on the serializer's determinism
//! (sorted keys via `Json::obj`, integer-clean number formatting). The
//! structural half exercises the solver-dependent ops (analyze / generic
//! sweep / calibrate / batch), asserting shapes and values rather than
//! bytes.
//!
//! The same protocol-shape corpus is embedded in `docs/SERVICE.md` as
//! `>>` / `<<` lines; the `protocol-conformance` CI step pipes those
//! through a live `bottlemod serve` so the docs cannot drift either.

use bottlemod::coordinator::service::serve_stdio;
use bottlemod::util::Json;

// Mirrors `api::test_fixtures::TINY_SPEC` (cfg(test) lib items are not
// visible to integration tests): a one-process spec solving to makespan 5.
const TINY_SPEC: &str = r#"{
  "processes": [
    {"name": "a", "max_progress": 10.0,
     "data": [{"req": {"type": "stream", "total": 10.0},
               "source": {"external_constant": 10.0}}],
     "resources": [{"req": {"type": "stream", "total": 5.0},
                    "source": {"constant": 1.0}}],
     "outputs": [{"name": "out", "type": "identity"}]}
  ]
}"#;

// Mirrors `api::test_fixtures::CHAIN_TSV`.
const CHAIN_TSV: &str = "task_id\tdeps\tstart\tcomplete\trealtime\tpcpu\trchar\twchar\tpeak_rss\n\
    dl\t-\t0\t10\t10\t1e9\t1e8\t1e8\t2e6\n\
    enc\tdl\t0\t20\t20\t100\t1e8\t5e7\t8e6\n";

/// Drive `serve_stdio` with one request per line; parsed responses back.
fn serve(lines: &[String]) -> Vec<Json> {
    let input: String = lines.iter().map(|l| format!("{l}\n")).collect();
    let mut out = Vec::new();
    serve_stdio(std::io::Cursor::new(input), &mut out).unwrap();
    let text = String::from_utf8(out).unwrap();
    let responses: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(responses.len(), lines.len(), "one response per request");
    responses
}

fn serve_one(line: &str) -> Json {
    serve(&[line.to_string()]).remove(0)
}

/// Exact response bytes for every protocol-shape case. This is the same
/// corpus `docs/SERVICE.md` embeds for the conformance CI step.
#[test]
fn protocol_golden_table() {
    let cases: &[(&str, &str)] = &[
        // valid v1
        (
            r#"{"v": 1, "id": 1, "op": "ping"}"#,
            r#"{"id":1,"ok":true,"result":{"pong":true},"v":1}"#,
        ),
        // legacy v0: flat shape, tagged deprecated
        (
            r#"{"op": "ping", "id": 8}"#,
            r#"{"deprecated":true,"id":8,"pong":true}"#,
        ),
        // malformed JSON: structured error, id echoed as null
        (
            "nope",
            r#"{"error":{"code":"bad_request","message":"bad request: json error at byte 0: expected 'null'"},"id":null,"ok":false,"v":1}"#,
        ),
        // unknown v1 op
        (
            r#"{"v": 1, "id": 2, "op": "frobnicate"}"#,
            r#"{"error":{"code":"unknown_op","message":"unknown op \"frobnicate\""},"id":2,"ok":false,"v":1}"#,
        ),
        // missing id (v1)
        (
            r#"{"v": 1, "op": "ping"}"#,
            r#"{"error":{"code":"bad_request","message":"request 'id' must be a non-negative integer"},"id":null,"ok":false,"v":1}"#,
        ),
        // missing id (legacy shim enforces it too, in the v0 dialect)
        (
            r#"{"op": "ping"}"#,
            r#"{"deprecated":true,"error":"request 'id' must be a non-negative integer","id":null}"#,
        ),
        // protocol version from the future
        (
            r#"{"v": 9, "id": 3, "op": "ping"}"#,
            r#"{"error":{"code":"unsupported_version","message":"unsupported protocol version 9 (supported: 1)"},"id":3,"ok":false,"v":1}"#,
        ),
        // unknown legacy op keeps the historical message text
        (
            r#"{"id": 9, "op": "nope"}"#,
            r#"{"deprecated":true,"error":"unknown op Some(\"nope\")","id":9}"#,
        ),
        // batch of pings through the worker pool
        (
            r#"{"v": 1, "id": 4, "op": "batch", "requests": [{"op": "ping"}, {"op": "ping"}]}"#,
            r#"{"id":4,"ok":true,"result":{"results":[{"ok":true,"result":{"pong":true}},{"ok":true,"result":{"pong":true}}]},"v":1}"#,
        ),
        // wrong-typed field
        (
            r#"{"v": 1, "id": 5, "op": "sweep", "perturbations": "nope"}"#,
            r#"{"error":{"code":"bad_request","message":"'perturbations' must be an array"},"id":5,"ok":false,"v":1}"#,
        ),
        // unknown perturbation kind: bad_request with the offending index
        (
            r#"{"v": 1, "id": 6, "op": "sweep", "workflow": "genomics", "perturbations": [{"kind": "warp"}]}"#,
            r#"{"error":{"code":"bad_request","detail":{"index":0},"message":"unknown perturbation kind 'warp'"},"id":6,"ok":false,"v":1}"#,
        ),
        // a knob the selected workflow does not expose names the
        // applicable vocabulary in the detail
        (
            r#"{"v": 1, "id": 7, "op": "sweep", "workflow": "genomics", "perturbations": [{"kind": "task1_cpu_scale", "value": 2}]}"#,
            r#"{"error":{"code":"bad_request","detail":{"applicable":["identity","fraction","link_rate_scale","input_scale","cpu_scale"]},"message":"perturbation 'task1_cpu_scale' applies to the video workflow only"},"id":7,"ok":false,"v":1}"#,
        ),
        // legacy empty sweep keeps its historical error text
        (
            r#"{"id": 10, "op": "sweep", "fractions": []}"#,
            r#"{"deprecated":true,"error":"sweep needs at least one fraction","id":10}"#,
        ),
        // masked stats: every time-varying field zeroed, byte-reproducible
        (
            r#"{"v": 1, "id": 16, "op": "stats", "mask": true}"#,
            r#"{"id":16,"ok":true,"result":{"inflight":0,"ops":{},"overloaded":0,"sessions_open":0,"sessions_total":0,"uptime_secs":0},"v":1}"#,
        ),
        // stats is service-scoped: rejected per item inside a batch
        (
            r#"{"v": 1, "id": 17, "op": "batch", "requests": [{"op": "stats"}]}"#,
            r#"{"id":17,"ok":true,"result":{"results":[{"error":{"code":"bad_request","message":"stats is service-scoped and cannot run inside a batch"},"ok":false}]},"v":1}"#,
        ),
        // sensitivity decode guard: h must be a positive number
        (
            r#"{"v": 1, "id": 18, "op": "sensitivity", "h": 0}"#,
            r#"{"error":{"code":"bad_request","message":"sensitivity 'h' must be a positive number"},"id":18,"ok":false,"v":1}"#,
        ),
    ];
    let lines: Vec<String> = cases.iter().map(|c| c.0.to_string()).collect();
    let input: String = lines.iter().map(|l| format!("{l}\n")).collect();
    let mut out = Vec::new();
    serve_stdio(std::io::Cursor::new(input), &mut out).unwrap();
    let text = String::from_utf8(out).unwrap();
    let got: Vec<&str> = text.lines().collect();
    assert_eq!(got.len(), cases.len());
    for ((req, want), got) in cases.iter().zip(got) {
        assert_eq!(got, *want, "request: {req}");
    }
}

/// A v1 analyze round-trip: envelope, id echo, result payload.
#[test]
fn v1_analyze() {
    let req = Json::obj(vec![
        ("v", Json::Num(1.0)),
        ("id", Json::Num(42.0)),
        ("op", Json::Str("analyze".into())),
        ("spec", Json::parse(TINY_SPEC).unwrap()),
    ]);
    let resp = serve_one(&req.to_string());
    assert_eq!(resp.get("v").as_f64(), Some(1.0));
    assert_eq!(resp.get("id").as_f64(), Some(42.0));
    assert_eq!(resp.get("ok").as_bool(), Some(true));
    assert_eq!(resp.get("deprecated"), &Json::Null, "v1 is not deprecated");
    let r = resp.get("result");
    assert!((r.get("makespan").as_f64().unwrap() - 5.0).abs() < 1e-6);
    assert_eq!(r.get("schedule").as_arr().unwrap().len(), 1);
}

/// The acceptance scenario on the wire: a generic sweep over the genomics
/// workflow with a non-fraction (pool-capacity) perturbation returns the
/// ranked bottleneck report with cache stats.
#[test]
fn v1_generic_sweep_genomics_pool_knob() {
    let line = r#"{"v": 1, "id": 11, "op": "sweep", "workflow": "genomics", "perturbations": [{"kind": "link_rate_scale", "value": 2}, {"kind": "identity"}]}"#;
    let resp = serve_one(line);
    assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
    let r = resp.get("result");
    assert_eq!(r.get("workflow").as_str(), Some("genomics"));
    let makespans = r.get("makespans").as_arr().unwrap();
    assert_eq!(makespans.len(), 2);
    assert!(makespans.iter().all(|m| m.as_f64().is_some()));
    // perturbations echoed in order
    let ps = r.get("perturbations").as_arr().unwrap();
    assert_eq!(ps[0].get("kind").as_str(), Some("link_rate_scale"));
    assert_eq!(ps[1].get("kind").as_str(), Some("identity"));
    // ranked report + per-request cache stats
    assert!(!r.get("ranked_bottlenecks").as_arr().unwrap().is_empty());
    assert!(r.get("cache").get("misses").as_f64().is_some());
    // best points into the batch
    let best = r.get("best");
    assert!(best.get("index").as_f64().is_some());
    assert!(best.get("makespan").as_f64().is_some());
}

/// Sweeping an inline spec under identity: the generic engine as a cached
/// analyzer for arbitrary workflows.
#[test]
fn v1_sweep_inline_spec() {
    let req = Json::obj(vec![
        ("v", Json::Num(1.0)),
        ("id", Json::Num(12.0)),
        ("op", Json::Str("sweep".into())),
        (
            "workflow",
            Json::obj(vec![("spec", Json::parse(TINY_SPEC).unwrap())]),
        ),
        (
            "perturbations",
            Json::Arr(vec![Json::obj(vec![("kind", Json::Str("identity".into()))])]),
        ),
    ]);
    let resp = serve_one(&req.to_string());
    assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
    let r = resp.get("result");
    assert_eq!(r.get("workflow").as_str(), Some("spec"));
    let mk = r.get("makespans").as_arr().unwrap()[0].as_f64().unwrap();
    assert!((mk - 5.0).abs() < 1e-6, "{mk}");
    // a video-only knob on a fixed workflow is a bad request
    let req = Json::obj(vec![
        ("v", Json::Num(1.0)),
        ("id", Json::Num(13.0)),
        ("op", Json::Str("sweep".into())),
        (
            "workflow",
            Json::obj(vec![("spec", Json::parse(TINY_SPEC).unwrap())]),
        ),
        (
            "perturbations",
            Json::Arr(vec![Json::obj(vec![
                ("kind", Json::Str("fraction".into())),
                ("value", Json::Num(0.5)),
            ])]),
        ),
    ]);
    let resp = serve_one(&req.to_string());
    assert_eq!(resp.get("ok").as_bool(), Some(false));
    assert_eq!(resp.get("error").get("code").as_str(), Some("bad_request"));
}

/// The sensitivity op on the wire: ranked knobs over an inline spec, a
/// point-estimate band (no residuals), and cache stats on the side.
#[test]
fn v1_sensitivity_inline_spec() {
    let req = Json::obj(vec![
        ("v", Json::Num(1.0)),
        ("id", Json::Num(21.0)),
        ("op", Json::Str("sensitivity".into())),
        (
            "workflow",
            Json::obj(vec![("spec", Json::parse(TINY_SPEC).unwrap())]),
        ),
    ]);
    let resp = serve_one(&req.to_string());
    assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
    let r = resp.get("result");
    assert_eq!(r.get("workflow").as_str(), Some("spec"));
    assert!((r.get("makespan").as_f64().unwrap() - 5.0).abs() < 1e-6);
    let band = r.get("band");
    assert_eq!(band.get("point_estimate").as_bool(), Some(true));
    assert_eq!(band.get("lower").as_f64(), band.get("upper").as_f64());
    let knobs = r.get("knobs").as_arr().unwrap();
    assert!(!knobs.is_empty(), "fixed models expose the scale knobs");
    for k in knobs {
        assert!(k.get("kind").as_str().is_some());
        assert!(k.get("gain_per_unit").as_f64().is_some());
    }
    // ranked: gain_per_unit non-increasing
    let gains: Vec<f64> = knobs
        .iter()
        .map(|k| k.get("gain_per_unit").as_f64().unwrap())
        .collect();
    assert!(gains.windows(2).all(|w| w[0] >= w[1]), "{gains:?}");
    assert!(r.get("cache").get("misses").as_f64().is_some());
}

/// v1 calibrate, including the new `tol` override; wrong-typed `tol` is a
/// structured bad request.
#[test]
fn v1_calibrate_with_tol() {
    let req = Json::obj(vec![
        ("v", Json::Num(1.0)),
        ("id", Json::Num(14.0)),
        ("op", Json::Str("calibrate".into())),
        ("tsv", Json::Str(CHAIN_TSV.into())),
        ("tol", Json::Num(0.05)),
    ]);
    let resp = serve_one(&req.to_string());
    assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
    let r = resp.get("result");
    assert_eq!(r.get("tasks").as_arr().unwrap().len(), 2);
    assert!(r.get("max_rel_err").as_f64().unwrap() < 0.01);

    let bad = serve_one(
        r#"{"v": 1, "id": 15, "op": "calibrate", "tsv": "x", "tol": "tight"}"#,
    );
    assert_eq!(bad.get("ok").as_bool(), Some(false));
    assert!(bad
        .get("error")
        .get("message")
        .as_str()
        .unwrap()
        .contains("tol"));
}

/// A heterogeneous batch through the pool: per-item outcomes in
/// submission order, failures isolated per item.
#[test]
fn v1_batch_heterogeneous() {
    let req = Json::obj(vec![
        ("v", Json::Num(1.0)),
        ("id", Json::Num(16.0)),
        ("op", Json::Str("batch".into())),
        (
            "requests",
            Json::Arr(vec![
                Json::obj(vec![("op", Json::Str("ping".into()))]),
                Json::obj(vec![
                    ("op", Json::Str("analyze".into())),
                    ("spec", Json::parse(TINY_SPEC).unwrap()),
                ]),
                Json::obj(vec![
                    ("op", Json::Str("analyze".into())),
                    ("spec", Json::obj(vec![])),
                ]),
                Json::obj(vec![
                    ("op", Json::Str("sweep".into())),
                    ("fractions", Json::arr_f64(&[0.5, 0.93])),
                ]),
            ]),
        ),
    ]);
    let resp = serve_one(&req.to_string());
    assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
    let results = resp.get("result").get("results").as_arr().unwrap();
    assert_eq!(results.len(), 4);
    assert_eq!(results[0].get("ok").as_bool(), Some(true));
    assert_eq!(results[0].get("result").get("pong").as_bool(), Some(true));
    let mk = results[1].get("result").get("makespan").as_f64().unwrap();
    assert!((mk - 5.0).abs() < 1e-6);
    assert_eq!(results[2].get("ok").as_bool(), Some(false));
    assert_eq!(
        results[2].get("error").get("code").as_str(),
        Some("invalid_spec")
    );
    // the sweep item uses the generic v1 result shape
    let sweep = results[3].get("result");
    assert_eq!(sweep.get("workflow").as_str(), Some("video"));
    assert_eq!(sweep.get("makespans").as_arr().unwrap().len(), 2);
    assert_eq!(sweep.get("best").get("index").as_f64(), Some(1.0));
}

/// The legacy requests documented in the pre-v1 `docs/SERVICE.md` still
/// round-trip, with their historical response fields, tagged deprecated.
#[test]
fn legacy_docs_requests_roundtrip() {
    // old docs: analyze with a spec object
    let analyze = Json::obj(vec![
        ("id", Json::Num(1.0)),
        ("op", Json::Str("analyze".into())),
        ("spec", Json::parse(TINY_SPEC).unwrap()),
    ]);
    // old docs: sweep with explicit fractions
    let sweep = r#"{"id": 2, "op": "sweep", "fractions": [0.25, 0.5, 0.75, 0.93]}"#;
    // old docs: calibrate with tsv text
    let calibrate = Json::obj(vec![
        ("id", Json::Num(3.0)),
        ("op", Json::Str("calibrate".into())),
        ("tsv", Json::Str(CHAIN_TSV.into())),
    ]);
    let resp = serve(&[
        analyze.to_string(),
        sweep.to_string(),
        calibrate.to_string(),
    ]);

    let a = &resp[0];
    assert_eq!(a.get("id").as_f64(), Some(1.0));
    assert_eq!(a.get("deprecated").as_bool(), Some(true));
    assert!((a.get("makespan").as_f64().unwrap() - 5.0).abs() < 1e-6);
    assert_eq!(a.get("schedule").as_arr().unwrap().len(), 1);

    let s = &resp[1];
    assert_eq!(s.get("id").as_f64(), Some(2.0));
    assert_eq!(s.get("deprecated").as_bool(), Some(true));
    assert_eq!(s.get("fractions").as_arr().unwrap().len(), 4);
    assert_eq!(s.get("totals").as_arr().unwrap().len(), 4);
    assert!((s.get("best_fraction").as_f64().unwrap() - 0.93).abs() < 1e-9);
    assert!(s.get("best_total").as_f64().unwrap() > 0.0);
    assert!(!s.get("ranked_bottlenecks").as_arr().unwrap().is_empty());
    assert!(s.get("cache").get("hit_rate").as_f64().is_some());

    let c = &resp[2];
    assert_eq!(c.get("id").as_f64(), Some(3.0));
    assert_eq!(c.get("deprecated").as_bool(), Some(true));
    assert_eq!(c.get("tasks").as_arr().unwrap().len(), 2);
    assert!(c.get("max_rel_err").as_f64().unwrap() < 0.01);
}

/// Error responses echo the request id whenever it was decodable.
#[test]
fn errors_echo_the_id() {
    // a v1 analyze with a missing spec
    let resp = serve_one(r#"{"v": 1, "id": 77, "op": "analyze"}"#);
    assert_eq!(resp.get("id").as_f64(), Some(77.0));
    assert_eq!(resp.get("ok").as_bool(), Some(false));
    // fractional ids are rejected and echoed as null
    let resp = serve_one(r#"{"v": 1, "id": 7.5, "op": "ping"}"#);
    assert_eq!(resp.get("id"), &Json::Null);
    assert_eq!(resp.get("error").get("code").as_str(), Some("bad_request"));
}
