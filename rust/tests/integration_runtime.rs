//! Integration tests over the PJRT runtime: the Rust exact engine, the
//! numpy-free Rust grid solver and the AOT-compiled JAX/Pallas artifacts
//! must agree. These tests skip (with a notice) when `artifacts/` has not
//! been built — run `make artifacts` first.

use bottlemod::model::{ProcessBuilder, ProcessInputs};
use bottlemod::pwfn::PwPoly;
use bottlemod::runtime::xla_sweep::{B, K, L, S2, T};
use bottlemod::runtime::Runtime;
use bottlemod::solver::{solve, SolverOpts};

const BIG: f32 = 1e30;

fn runtime() -> Option<Runtime> {
    if !Runtime::backend_available() {
        eprintln!("skipping: PJRT execution backend not compiled in");
        return None;
    }
    if !Runtime::default_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(&Runtime::default_dir()).expect("runtime"))
}

/// The L2 grid-solver artifact reproduces the exact solver on a scenario
/// with a mid-flight allocation change (I_R piece boundary).
#[test]
fn grid_solve_artifact_matches_exact_solver() {
    let Some(mut rt) = runtime() else { return };
    let name = format!("grid_solve_pd_b{B}_k{K}_l{L}_s{S2}_t{T}");

    // rust exact: 100 progress, R'=1, allocation 1 until t=20 then 4
    let proc = ProcessBuilder::new("t", 100.0)
        .stream_resource("cpu", 100.0)
        .build();
    let inputs = ProcessInputs {
        data: vec![],
        resources: vec![PwPoly::step(0.0, 20.0, 1.0, 4.0)],
        start_time: 0.0,
    };
    let exact = solve(&proc, &inputs, &SolverOpts::default()).unwrap();
    let exact_finish = exact.finish_time.unwrap(); // 40.0

    // artifact inputs, batch-0 carries the case; the rest idle
    let span = 120.0f64;
    let ts: Vec<f32> = (0..T).map(|i| (i as f64 * span / T as f64) as f32).collect();
    let pd = vec![BIG; B * K * T]
        .iter()
        .enumerate()
        .map(|(i, _)| if i / (K * T) == 0 { 100.0 } else { BIG })
        .collect::<Vec<f32>>();
    let mut rbreaks = vec![BIG; B * L * (S2 + 1)];
    let mut rslopes = vec![0f32; B * L * S2];
    rbreaks[0] = 0.0;
    rslopes[0] = 1.0;
    let mut rin = vec![0f32; B * L * T];
    for (t_idx, tv) in ts.iter().enumerate() {
        rin[t_idx] = if *tv < 20.0 { 1.0 } else { 4.0 };
    }
    let mut target = vec![BIG; B];
    target[0] = 100.0;

    let out = rt
        .execute_f32(
            &name,
            &[
                (&pd, &[B, K, T]),
                (&rbreaks, &[B, L, S2 + 1]),
                (&rslopes, &[B, L, S2]),
                (&rin, &[B, L, T]),
                (&ts, &[T]),
                (&target, &[B]),
            ],
        )
        .unwrap();
    let makespan = out[1][0] as f64;
    let dt = span / T as f64;
    assert!(
        (makespan - exact_finish).abs() <= 3.0 * dt,
        "artifact {makespan} vs exact {exact_finish}"
    );
    // progress at t=20 should be ~20
    let i20 = ts.iter().position(|&t| t >= 20.0).unwrap();
    let p20 = out[0][i20] as f64;
    assert!((p20 - 20.0).abs() < 1.0, "{p20}");
}

/// The Pallas kernel artifact agrees with the Rust pwfn engine on a batch
/// of randomly generated piecewise quadratics.
#[test]
fn eval_pw_artifact_matches_pwfn_on_random_batch() {
    let Some(mut rt) = runtime() else { return };
    let name = "eval_pw_b64_s16_d4_t1024";
    let info = rt.info(name).expect("artifact").clone();
    let (b, s1) = (info.inputs[0][0], info.inputs[0][1]);
    let s = s1 - 1;
    let d = info.inputs[1][2];
    let t = info.inputs[2][0];

    let mut rng = bottlemod::util::Rng::new(2024);
    let mut breaks = vec![BIG as f32; b * s1];
    let mut coeffs = vec![0f32; b * s * d];
    let mut rust_fns = vec![];
    for i in 0..b {
        let pieces = 1 + rng.below(4);
        let mut bks = vec![0.0f64];
        for j in 0..pieces - 1 {
            bks.push(bks[j] + rng.range(3.0, 20.0));
        }
        bks.push(f64::INFINITY);
        let mut polys = vec![];
        for j in 0..pieces {
            let c: Vec<f64> = (0..3).map(|_| rng.range(-2.0, 2.0)).collect();
            polys.push(bottlemod::pwfn::Poly::new(c.clone()));
            for (deg, cv) in c.iter().enumerate() {
                coeffs[(i * s + j) * d + deg] = *cv as f32;
            }
            breaks[i * s1 + j] = bks[j] as f32;
        }
        breaks[i * s1 + pieces] = BIG;
        rust_fns.push(PwPoly::new(bks, polys));
    }
    let ts: Vec<f32> = (0..t).map(|i| i as f32 * 0.07).collect();
    let out = rt
        .execute_f32(
            name,
            &[
                (&breaks, &info.inputs[0]),
                (&coeffs, &info.inputs[1]),
                (&ts, &info.inputs[2]),
            ],
        )
        .unwrap();
    for i in (0..b).step_by(7) {
        for ti in (0..t).step_by(131) {
            let want = rust_fns[i].eval(ts[ti] as f64);
            let got = out[0][i * t + ti] as f64;
            assert!(
                (want - got).abs() < 1e-3 * (1.0 + want.abs()),
                "fn {i} t={}: rust {want} vs artifact {got}",
                ts[ti]
            );
        }
    }
}

/// The full batched Fig 7 path against the threaded exact sweep, end to end.
#[test]
fn batched_and_exact_sweeps_agree_densely() {
    let Some(mut rt) = runtime() else { return };
    use bottlemod::coordinator::sweeper::{exact_sweep, fig7_fractions};
    use bottlemod::workflow::scenario::VideoScenario;
    let sc = VideoScenario::default();
    let fractions = fig7_fractions(60);
    let exact = exact_sweep(&sc, &fractions, 4);
    let batched = bottlemod::runtime::fig7_sweep(&mut rt, &sc, &fractions).unwrap();
    let mut worst = 0.0f64;
    for (a, b) in exact.totals.iter().zip(&batched.totals) {
        worst = worst.max((a - b).abs());
    }
    assert!(worst < 3.0, "max divergence {worst} s");
}
