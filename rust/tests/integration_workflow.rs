//! Cross-module integration tests: spec → engine → testbed → DES → service,
//! all on the same workloads.

use bottlemod::api::{Request, Response};
use bottlemod::coordinator::service::{run_job, Job};
use bottlemod::des;
use bottlemod::solver::SolverOpts;
use bottlemod::testbed::fluid::{execute, FluidOpts};
use bottlemod::testbed::video::VideoTestbed;
use bottlemod::workflow::engine::{analyze_fixpoint, analyze};
use bottlemod::workflow::scenario::VideoScenario;

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() < tol
}

/// The spec file shipped with the examples must load and reproduce the
/// built-in scenario's prediction through the service front end.
#[test]
fn example_spec_through_service() {
    let spec = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("examples/specs/video.json"),
    )
    .expect("examples/specs/video.json");
    let r = run_job(&Job {
        id: 1,
        request: Request::Analyze { spec },
    });
    let res = match r.outcome.expect("analysis succeeds") {
        Response::Analyze(a) => a,
        other => panic!("unexpected response {other:?}"),
    };
    let mk = res.makespan.expect("makespan");
    assert!(close(mk, 263.0, 2.0), "{mk}");
    // the schedule includes all five processes
    assert_eq!(res.schedule.len(), 5);
    // at 50:50 the dominant early bottleneck is the shared link
    assert!(res.bottlenecks.iter().any(|b| b.bottleneck == "res:link"));
}

/// Prediction, fluid execution and concrete testbed agree across fractions.
#[test]
fn three_way_agreement_across_fractions() {
    for f in [0.2, 0.5, 0.8, 0.95] {
        let sc = VideoScenario::default().with_fraction(f);
        let (wf, _) = sc.build();
        let predicted = analyze_fixpoint(&wf, &SolverOpts::default(), 6)
            .unwrap()
            .makespan
            .unwrap();
        let fluid = execute(
            &wf,
            &FluidOpts {
                dt: 0.05,
                ..FluidOpts::default()
            },
        )
        .makespan
        .unwrap();
        let testbed = VideoTestbed::new(sc).run(None).total;
        assert!(
            close(predicted, fluid, 0.01 * predicted + 1.0),
            "f={f}: predicted {predicted} vs fluid {fluid}"
        );
        assert!(
            close(predicted, testbed, 0.02 * predicted + 1.0),
            "f={f}: predicted {predicted} vs testbed {testbed}"
        );
    }
}

/// The DES (no streaming) must be pessimistic vs BottleMod wherever the
/// workflow actually pipelines — and both must rank orderings identically.
#[test]
fn des_is_pessimistic_but_consistent() {
    let sc = VideoScenario::default();
    let (wf, _) = sc.build();
    let bm = analyze_fixpoint(&wf, &SolverOpts::default(), 6)
        .unwrap()
        .makespan
        .unwrap();
    let des_r = des::video::run(&sc, 1e6);
    assert!(
        des_r.makespan > bm,
        "DES {} should exceed streaming-aware {}",
        des_r.makespan,
        bm
    );
    // within ~15%: the only modeling gap is pipelining of task 2 + the
    // decode overlap
    assert!(des_r.makespan < 1.20 * bm, "{} vs {}", des_r.makespan, bm);
}

/// Single-pass analyze (the paper's procedure) equals the fixpoint when the
/// prioritized consumer is analyzed first and finishes first.
#[test]
fn single_pass_suffices_for_high_fractions() {
    for f in [0.6, 0.8, 0.95] {
        let sc = VideoScenario::default().with_fraction(f);
        let (wf, _) = sc.build();
        let one = analyze(&wf, &SolverOpts::default()).unwrap().makespan.unwrap();
        let fx = analyze_fixpoint(&wf, &SolverOpts::default(), 6)
            .unwrap()
            .makespan
            .unwrap();
        assert!(close(one, fx, 0.5), "f={f}: {one} vs {fx}");
    }
}

/// Scaling the input size scales the makespan linearly (same rates), while
/// solver events stay constant — end-to-end §6 property.
#[test]
fn makespan_scales_events_do_not() {
    let base = VideoScenario::default().with_fraction(0.5);
    let (wf1, _) = base.clone().build();
    let a1 = analyze_fixpoint(&wf1, &SolverOpts::default(), 6).unwrap();
    let (wf10, _) = base.with_input_size(11.37486559e9).build();
    let a10 = analyze_fixpoint(&wf10, &SolverOpts::default(), 6).unwrap();
    let (m1, m10) = (a1.makespan.unwrap(), a10.makespan.unwrap());
    assert!(close(m10, 10.0 * m1, 0.02 * m10), "{m1} -> {m10}");
    assert!(a10.events <= a1.events + 2, "{} -> {}", a1.events, a10.events);
}

/// Buffered-data metric (paper eq. 8) on the video workflow: task 1's input
/// buffer fills during the download (the named-pipe backlog), then drains.
#[test]
fn buffered_data_on_video_workflow() {
    let sc = VideoScenario::default().with_fraction(0.5);
    let (wf, nodes) = sc.clone().build();
    let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 6).unwrap();
    let a = &wa.analyses[nodes.task1];
    let p = &wf.nodes[nodes.task1].process;
    let inputs = &wa.inputs[nodes.task1];
    // mid-download: everything downloaded so far is buffered (burst task)
    let buf = a.buffered_data_sampled(p, inputs, 0, &[100.0]);
    let expected = sc.link_rate * 0.5 * 100.0;
    assert!(
        close(buf[0], expected, 0.02 * expected),
        "{} vs {}",
        buf[0],
        expected
    );
}
