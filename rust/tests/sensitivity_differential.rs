//! Differential validation of the sensitivity subsystem (`sense`,
//! docs/SENSITIVITY.md) against the generator's topology families:
//!
//! * on every smooth knob the analytic active-segment derivative and the
//!   central finite difference must agree to 1e-6 relative — across all
//!   five topology shapes and many seeds, not just the papers' scenarios;
//! * confidence bands are ordered (lower ≤ median ≤ upper), nest
//!   monotonically in the residual magnitude, and pin the median to the
//!   caller's baseline bit-for-bit;
//! * the canonical report JSON is byte-deterministic and independent of
//!   the stencil batch's thread count;
//! * zero residuals collapse the band to the point estimate without
//!   spending a single extra solver event.

use std::sync::Arc;

use bottlemod::runtime::{FixedWorkflow, SweepModel};
use bottlemod::sense::{analyze, confidence_band, SenseOpts};
use bottlemod::solver::SolverOpts;
use bottlemod::util::Rng;
use bottlemod::workflow::generator::{generate, GeneratorOpts, Topology};
use bottlemod::workflow::Workflow;

/// A small generated workflow for one (shape, seed) cell of the sweep.
fn generated(shape: Topology, seed: u64) -> Workflow {
    let gopts = GeneratorOpts {
        topology: shape,
        width_jitter: 0.2,
        pool_residual_prob: 0.3,
        ..GeneratorOpts::default()
    }
    .target_nodes(12);
    let wf = generate(&mut Rng::new(seed), &gopts);
    wf.validate().expect("generated workflows validate");
    wf
}

fn model_for(shape: Topology, seed: u64) -> Arc<dyn SweepModel> {
    Arc::new(FixedWorkflow::new("gen", generated(shape, seed)))
}

/// The 1e-6 agreement contract: on every knob the stencil did not flag as
/// insensitive or non-smooth, the closed-form derivative of the fitted
/// active-segment model matches the central difference.
#[test]
fn closed_form_matches_central_difference_across_topologies() {
    let opts = SenseOpts {
        threads: 1,
        ..SenseOpts::default()
    };
    let mut checked_models = 0usize;
    let mut checked_knobs = 0usize;
    for shape in Topology::ALL {
        for seed in 0..5u64 {
            let model = model_for(shape, seed);
            let report = match analyze(&model, &[], &opts) {
                Ok(r) => r,
                // a cell whose baseline never finishes has no gradient to
                // check; the coverage floor below keeps this path honest
                Err(_) => continue,
            };
            assert!(report.makespan > 0.0, "{shape:?} seed {seed}");
            checked_models += 1;
            for k in &report.knobs {
                let (Some(cd), Some(cf)) = (k.derivative, k.closed_form) else {
                    continue;
                };
                if k.insensitive || k.non_smooth {
                    continue;
                }
                let denom = cd.abs().max(cf.abs());
                let rel = (cd - cf).abs() / denom;
                assert!(
                    rel <= 1e-6,
                    "{shape:?} seed {seed} knob {}: cd {cd} vs cf {cf} (rel {rel:.3e})",
                    k.kind
                );
                checked_knobs += 1;
            }
        }
    }
    assert!(
        checked_models >= 20,
        "only {checked_models} of 25 generated models produced a report"
    );
    assert!(
        checked_knobs >= 10,
        "only {checked_knobs} smooth knobs checked — the sweep lost its teeth"
    );
}

/// Bands are ordered, nest in the residual magnitude, and keep the median
/// pinned to the supplied baseline exactly.
#[test]
fn bands_are_ordered_and_monotone_in_residuals() {
    let solver = SolverOpts::default();
    let mut shapes_checked = 0usize;
    let mut any_widened = false;
    for shape in Topology::ALL {
        let wf = generated(shape, 7);
        let baseline = match bottlemod::workflow::engine::analyze_fixpoint(&wf, &solver, 6) {
            Ok(wa) => match wa.makespan {
                Some(m) => m,
                None => continue,
            },
            Err(_) => continue,
        };
        shapes_checked += 1;
        let mut widths = Vec::new();
        for eps in [0.05, 0.15, 0.4] {
            let residuals = vec![eps; wf.nodes.len()];
            let r = confidence_band(&wf, &residuals, Some(baseline), &solver, 6, None, 0)
                .expect("band solve");
            let b = r.band;
            assert!(
                b.lower <= b.median && b.median <= b.upper,
                "{shape:?} eps {eps}: [{}, {}, {}]",
                b.lower,
                b.median,
                b.upper
            );
            assert_eq!(
                b.median.to_bits(),
                baseline.to_bits(),
                "{shape:?}: median must be the caller's baseline, bit for bit"
            );
            any_widened |= !b.is_point();
            widths.push(b.upper - b.lower);
        }
        // a purely data-limited workflow may legitimately ignore the
        // resource-side shift, but the width can never shrink as the
        // residuals grow
        assert!(
            widths.windows(2).all(|w| w[0] <= w[1] + 1e-12),
            "{shape:?}: band width must grow with the residuals: {widths:?}"
        );
    }
    assert!(shapes_checked >= 3, "only {shapes_checked} shapes solved");
    assert!(any_widened, "no shape produced a non-point band at eps 0.4");
}

/// Same model, same residuals, any thread count: byte-identical canonical
/// report JSON.
#[test]
fn report_json_is_byte_deterministic() {
    let mut shapes_checked = 0usize;
    for shape in [Topology::Layered, Topology::ScatterGather, Topology::Genomics] {
        let residuals = vec![0.1; generated(shape, 3).nodes.len()];
        let mut encodings = Vec::new();
        for threads in [1usize, 4] {
            let opts = SenseOpts {
                threads,
                ..SenseOpts::default()
            };
            let model = model_for(shape, 3);
            match analyze(&model, &residuals, &opts) {
                Ok(report) => encodings.push(report.to_json().to_string()),
                Err(_) => break, // unfinishable cell: nothing to compare
            }
        }
        if encodings.len() == 2 {
            assert_eq!(
                encodings[0], encodings[1],
                "{shape:?}: report bytes must not depend on the thread count"
            );
            shapes_checked += 1;
        }
    }
    assert!(shapes_checked >= 2, "only {shapes_checked} shapes compared");
}

/// All-zero residuals: no extra solves, a point band, zero uncertainty on
/// every knob.
#[test]
fn zero_residuals_collapse_to_the_point_estimate() {
    let solver = SolverOpts::default();
    let (wf, baseline) = (0..20u64)
        .find_map(|seed| {
            let wf = generated(Topology::FanInJoin, seed);
            bottlemod::workflow::engine::analyze_fixpoint(&wf, &solver, 6)
                .ok()
                .and_then(|wa| wa.makespan)
                .map(|m| (wf, m))
        })
        .expect("some fan-in seed yields a finite makespan");
    let residuals = vec![0.0; wf.nodes.len()];
    let r = confidence_band(&wf, &residuals, Some(baseline), &solver, 6, None, 0)
        .expect("band solve");
    assert!(r.band.is_point(), "{:?}", r.band);
    assert_eq!(r.events, 0, "zero residuals must not spend solver events");
    assert!(r.samples.is_empty());
    assert_eq!(r.band.median.to_bits(), baseline.to_bits());

    let model: Arc<dyn SweepModel> = Arc::new(FixedWorkflow::new("gen", wf));
    let report = analyze(
        &model,
        &residuals,
        &SenseOpts {
            threads: 1,
            ..SenseOpts::default()
        },
    )
    .expect("analyze");
    assert!(report.band.is_point());
    for k in &report.knobs {
        assert_eq!(
            k.uncertainty, 0.0,
            "knob {}: a point band carries no uncertainty",
            k.kind
        );
    }
}
