//! The live monitor's acceptance tests: stream a fluid-testbed execution
//! into a [`Monitor`] as mid-run trace snapshots and require
//!
//! * bit-for-bit identity with the cold calibrate+solve pipeline at every
//!   prefix (the monitor's incrementality contract),
//! * a prediction that tracks the observation frontier monotonically on a
//!   contention-free chain, and
//! * an advisory fired exactly when the Fig 5 pool bottleneck shifts.

use std::sync::Arc;

use bottlemod::live::{Monitor, MonitorOpts};
use bottlemod::model::ProcessBuilder;
use bottlemod::pwfn::PwPoly;
use bottlemod::solver::SolverOpts;
use bottlemod::testbed::fluid::{
    execute, export_trace, export_trace_until, FluidOpts, FluidRun,
};
use bottlemod::trace::{calibrate_trace, write_io_log, write_tsv, CalibrateOpts};
use bottlemod::workflow::graph::{DataSource, ResourceSource, StartRule, Workflow};
use bottlemod::workflow::scenario::VideoScenario;

/// download → streaming transcode → burst archive (the calibration
/// round-trip chain: dl [0,10], xcode [0,20], arch [20,25]).
fn chain() -> Workflow {
    let mut wf = Workflow::new();
    let dl = ProcessBuilder::new("dl", 1e8)
        .stream_data("remote", 1e8)
        .stream_resource("link", 1e8)
        .identity_output("file")
        .build();
    let d = wf.add_node(
        dl,
        vec![DataSource::External(PwPoly::constant(1e8))],
        vec![ResourceSource::Fixed(PwPoly::constant(1e7))],
        StartRule::default(),
    );
    let xcode = ProcessBuilder::new("xcode", 5e7)
        .stream_data("in", 1e8)
        .stream_resource("cpu", 20.0)
        .identity_output("out")
        .build();
    let x = wf.add_node(
        xcode,
        vec![DataSource::ProcessOutput { node: d, output: 0 }],
        vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
        StartRule::default(),
    );
    let arch = ProcessBuilder::new("arch", 5e7)
        .burst_data("in", 5e7)
        .stream_resource("io", 5.0)
        .identity_output("tar")
        .build();
    wf.add_node(
        arch,
        vec![DataSource::ProcessOutput { node: x, output: 0 }],
        vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
        StartRule::default(),
    );
    wf
}

fn run_fluid(wf: &Workflow) -> FluidRun {
    let run = execute(
        wf,
        &FluidOpts {
            dt: 0.005,
            sample_every: 0.1,
            ..FluidOpts::default()
        },
    );
    assert!(run.makespan.is_some(), "fluid run must finish");
    run
}

/// Feed one mid-run snapshot (full TSV re-send + accumulated I/O text) and
/// return the report; the monitor upserts rows and collapses re-sent
/// samples, so re-sending whole snapshots is the lazy client's protocol.
fn feed_snapshot(
    m: &mut Monitor,
    wf: &Workflow,
    run: &FluidRun,
    t: f64,
) -> bottlemod::live::FeedReport {
    let (trace, series) = export_trace_until(wf, run, t).expect("snapshot export");
    let rep = m
        .feed(Some(&write_tsv(&trace)), Some(&write_io_log(&series)))
        .expect("feed");
    assert!(rep.stale.is_none(), "t={t}: stale {:?}", rep.stale);
    rep
}

/// Acceptance criterion: after every event the monitor's prediction is
/// bit-for-bit what a cold parse → calibrate → assemble → solve of the
/// accumulated text produces — including the final state, where the
/// accumulated trace must equal the full export itself.
#[test]
fn incremental_feed_is_bit_identical_to_cold_at_every_prefix() {
    let wf = chain();
    let run = run_fluid(&wf);
    let mk = run.makespan.unwrap();

    let mut m = Monitor::new("chain", None, MonitorOpts::default());
    for t in [6.0, 15.0, 22.0, mk + 1.0] {
        let rep = feed_snapshot(&mut m, &wf, &run, t);
        let (_, cold) = calibrate_trace(
            &m.effective_tsv(),
            Some(m.io_log()),
            &CalibrateOpts::default(),
            &SolverOpts::default(),
        )
        .expect("cold pipeline");
        let live = rep.snapshot.expect("snapshot").makespan;
        assert_eq!(
            live.map(f64::to_bits),
            cold.predicted_makespan.map(f64::to_bits),
            "prefix t={t}: live {live:?} vs cold {:?}",
            cold.predicted_makespan
        );
    }

    // the accumulated effective trace converged to the full export…
    let (full_trace, _) = export_trace(&wf, &run).expect("full export");
    assert_eq!(m.effective_tsv(), write_tsv(&full_trace));
    // …and the prediction is within the replay validator's usual bound
    let pred = m.snapshot().unwrap().makespan.unwrap();
    assert!((pred - mk).abs() / mk < 0.03, "predicted {pred} vs observed {mk}");
    assert_eq!(m.events(), 4);
}

/// On a contention-free chain the live prediction tracks progress
/// monotonically: the predicted horizon advances strictly with every
/// snapshot, and — because the models are fitted from the observations
/// themselves — the predicted-remaining beyond the newest observation
/// stays pinned near zero at every prefix, hitting (essentially) zero
/// once the run is fully observed.
#[test]
fn chain_prediction_tracks_the_frontier_monotonically() {
    let wf = chain();
    let run = run_fluid(&wf);
    let mk = run.makespan.unwrap(); // ~25 s

    let mut m = Monitor::new("chain", None, MonitorOpts::default());
    let mut last_now = 0.0f64;
    let mut last_makespan = 0.0f64;
    for t in [4.0, 8.0, 12.0, 16.0, 20.0, 24.0, mk + 1.0] {
        let rep = feed_snapshot(&mut m, &wf, &run, t);
        let snap = rep.snapshot.expect("snapshot");
        let pred = snap.makespan.expect("finite prediction");
        assert!(
            snap.now > last_now,
            "t={t}: now {} did not advance past {last_now}",
            snap.now
        );
        assert!(
            pred > last_makespan,
            "t={t}: predicted horizon {pred} did not advance past {last_makespan}"
        );
        // the prediction hugs the observation frontier (fit tolerance)
        let remaining = snap.remaining.expect("remaining");
        assert!(
            remaining <= 0.05 * snap.now.max(1.0),
            "t={t}: remaining {remaining} strays from the frontier (now {})",
            snap.now
        );
        assert!(!snap.ranked.is_empty(), "t={t}: no attribution");
        last_now = snap.now;
        last_makespan = pred;
    }
    // fully observed: remaining collapses to the replay error (< 3 %)
    let snap = m.snapshot().unwrap();
    assert!(snap.remaining.unwrap() < 0.03 * mk, "{snap:?}");
    assert!((snap.now - mk).abs() < 1e-9);
}

/// The Fig 5 story end to end: stream the 50:50 video run; while the
/// shared link binds the downloads no advisory fires, and the single feed
/// that first observes task 1's post-download phase — the pool bottleneck
/// has shifted from the link to task 1 — carries exactly one advisory,
/// with a link-split recommendation from the attached allocation model.
#[test]
fn advisory_fires_exactly_on_the_video_bottleneck_shift() {
    let (wf, _) = VideoScenario::default().build();
    let run = execute(
        &wf,
        &FluidOpts {
            dt: 0.02,
            sample_every: 0.5,
            ..FluidOpts::default()
        },
    );
    assert!(run.makespan.is_some(), "video run must finish");

    let mut m = Monitor::new(
        "video",
        Some(Arc::new(VideoScenario::default())),
        MonitorOpts::default(),
    );

    // downloads in flight: establishes the baseline, no advisory yet
    let rep = feed_snapshot(&mut m, &wf, &run, 50.0);
    let base = rep.snapshot.as_ref().unwrap().bottleneck.clone().unwrap();
    assert_ne!(base.0, "task1-reverse", "{base:?}");
    assert!(rep.advisory.is_none(), "{:?}", rep.advisory);

    // downloads done, task 1 now the binding task: the shift fires once,
    // with a recommendation from the video allocation model
    let rep = feed_snapshot(&mut m, &wf, &run, 200.0);
    let adv = rep.advisory.expect("advisory on the shift");
    assert_eq!(adv.shift.from, Some(base));
    assert_eq!(adv.shift.to.0, "task1-reverse", "{:?}", adv.shift);
    let rec = adv.recommendation.expect("allocation recommendation");
    assert!(
        rec.best_fraction > 0.0 && rec.best_fraction < 1.0,
        "{rec:?}"
    );
    assert!(rec.gain > 0.0, "{rec:?}");

    // same regime a little later: no new advisory
    let rep = feed_snapshot(&mut m, &wf, &run, 230.0);
    assert!(rep.advisory.is_none(), "{:?}", rep.advisory);
    assert_eq!(m.status().advisories, 1);
}
