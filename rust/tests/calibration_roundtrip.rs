//! The calibration subsystem's acceptance test: synthesize a workflow,
//! execute it on the fluid testbed (independent ground truth), export the
//! run through the raw trace formats (TSV + I/O series **text**, so the
//! parsers are on the round trip), calibrate models from the text, replay
//! through the analytic solver — and require per-task completion-time
//! error ≤ 2 %.

use bottlemod::model::ProcessBuilder;
use bottlemod::pwfn::PwPoly;
use bottlemod::solver::SolverOpts;
use bottlemod::testbed::fluid::{execute, export_trace, FluidOpts};
use bottlemod::trace::{
    calibrate_trace, write_io_log, write_tsv, CalibrateOpts, ModelSource, ReplayReport,
};
use bottlemod::workflow::graph::{DataSource, ResourceSource, StartRule, Workflow};
use bottlemod::workflow::scenario::VideoScenario;

const TOL: f64 = 0.02;

/// Execute, export as text, calibrate from the text, replay; assert the
/// per-task error bound and return the report for extra checks.
fn roundtrip(wf: &Workflow, dt: f64, sample_every: f64) -> ReplayReport {
    let run = execute(
        wf,
        &FluidOpts {
            dt,
            sample_every,
            ..FluidOpts::default()
        },
    );
    assert!(run.makespan.is_some(), "fluid run must finish");
    let (tsv_trace, series) = export_trace(wf, &run).expect("export");
    let tsv = write_tsv(&tsv_trace);
    let io_log = write_io_log(&series);
    let (cal, report) = calibrate_trace(
        &tsv,
        Some(&io_log),
        &CalibrateOpts::default(),
        &SolverOpts::default(),
    )
    .expect("calibrate");
    assert_eq!(cal.tasks.len(), wf.nodes.len());
    for r in &report.per_task {
        let err = r.rel_err.unwrap_or_else(|| panic!("{}: no replay error", r.id));
        assert!(
            err <= TOL,
            "task '{}': predicted {:?} vs observed {:?} (rel err {err})",
            r.id,
            r.predicted,
            r.observed
        );
    }
    report
}

/// download → streaming transcode → burst archive.
fn chain() -> Workflow {
    let mut wf = Workflow::new();
    let dl = ProcessBuilder::new("dl", 1e8)
        .stream_data("remote", 1e8)
        .stream_resource("link", 1e8)
        .identity_output("file")
        .build();
    let d = wf.add_node(
        dl,
        vec![DataSource::External(PwPoly::constant(1e8))],
        vec![ResourceSource::Fixed(PwPoly::constant(1e7))],
        StartRule::default(),
    );
    let xcode = ProcessBuilder::new("xcode", 5e7)
        .stream_data("in", 1e8)
        .stream_resource("cpu", 20.0)
        .identity_output("out")
        .build();
    let x = wf.add_node(
        xcode,
        vec![DataSource::ProcessOutput { node: d, output: 0 }],
        vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
        StartRule::default(),
    );
    let arch = ProcessBuilder::new("arch", 5e7)
        .burst_data("in", 5e7)
        .stream_resource("io", 5.0)
        .identity_output("tar")
        .build();
    wf.add_node(
        arch,
        vec![DataSource::ProcessOutput { node: x, output: 0 }],
        vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
        StartRule::default(),
    );
    wf
}

#[test]
fn chain_roundtrip_within_two_percent() {
    let report = roundtrip(&chain(), 0.005, 0.1);
    // the chain is dl(10) → xcode(20, resource-limited) → arch(25)
    let mk = report.predicted_makespan.unwrap();
    assert!((mk - 25.0).abs() < 0.5, "{mk}");
    assert!((report.observed_makespan.unwrap() - 25.0).abs() < 0.5);
}

/// Diamond: src fans out to a streaming and a bursting branch, joined by a
/// two-input mux — exercising the multi-dependency barrier wiring.
#[test]
fn diamond_roundtrip_within_two_percent() {
    let mut wf = Workflow::new();
    let src = ProcessBuilder::new("src", 1e8)
        .stream_data("remote", 1e8)
        .stream_resource("link", 1e8)
        .identity_output("file")
        .build();
    let s = wf.add_node(
        src,
        vec![DataSource::External(PwPoly::constant(1e8))],
        vec![ResourceSource::Fixed(PwPoly::constant(1e7))],
        StartRule::default(),
    );
    let a = ProcessBuilder::new("branch-a", 5e7)
        .stream_data("in", 1e8)
        .stream_resource("cpu", 25.0)
        .identity_output("out")
        .build();
    let na = wf.add_node(
        a,
        vec![DataSource::ProcessOutput { node: s, output: 0 }],
        vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
        StartRule::default(),
    );
    let b = ProcessBuilder::new("branch-b", 1e8)
        .burst_data("in", 1e8)
        .stream_resource("io", 8.0)
        .identity_output("out")
        .build();
    let nb = wf.add_node(
        b,
        vec![DataSource::ProcessOutput { node: s, output: 0 }],
        vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
        StartRule::default(),
    );
    let join = ProcessBuilder::new("join", 1.5e8)
        .burst_data("ina", 5e7)
        .burst_data("inb", 1e8)
        .stream_resource("io", 6.0)
        .identity_output("result")
        .build();
    wf.add_node(
        join,
        vec![
            DataSource::ProcessOutput { node: na, output: 0 },
            DataSource::ProcessOutput { node: nb, output: 0 },
        ],
        vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
        StartRule::default(),
    );
    let report = roundtrip(&wf, 0.005, 0.1);
    // src 10; a resource-limited 25; b bursts at 10 + 8 = 18; join 25 + 6
    let mk = report.predicted_makespan.unwrap();
    assert!((mk - 31.0).abs() < 0.6, "{mk}");
}

/// The full Fig 5 workflow — shared link pool with fraction + residual
/// consumers, release on completion, a burst task, a stream task and a
/// barrier mux — round-trips through the trace formats too.
#[test]
fn video_workflow_roundtrip_within_two_percent() {
    let (wf, _) = VideoScenario::default().build();
    let report = roundtrip(&wf, 0.02, 0.5);
    // consistency with the independently-predicted hand model
    let hand = bottlemod::workflow::engine::analyze_fixpoint(
        &wf,
        &SolverOpts::default(),
        6,
    )
    .unwrap()
    .makespan
    .unwrap();
    let calibrated = report.predicted_makespan.unwrap();
    assert!(
        (calibrated - hand).abs() / hand < 0.03,
        "calibrated {calibrated} vs hand model {hand}"
    );
}

/// The bundled fixtures parse and replay: with the I/O series the encode
/// task is series-fitted; TSV-only falls back to the summary heuristics
/// (the mux's high peak RSS selects the burst shape) — both within 2 %.
#[test]
fn bundled_fixtures_replay() {
    let tsv = include_str!("../examples/traces/demo.tsv");
    let io = include_str!("../examples/traces/demo_io.log");

    let (cal, report) = calibrate_trace(
        tsv,
        Some(io),
        &CalibrateOpts::default(),
        &SolverOpts::default(),
    )
    .expect("fixtures calibrate");
    assert_eq!(cal.tasks[1].id, "enc");
    assert_eq!(cal.tasks[1].source, ModelSource::Series);
    assert!(report.max_rel_err.unwrap() <= TOL, "{:?}", report.per_task);
    assert!((report.predicted_makespan.unwrap() - 23.0).abs() < 0.2);

    let (cal2, report2) =
        calibrate_trace(tsv, None, &CalibrateOpts::default(), &SolverOpts::default())
            .expect("tsv-only calibrates");
    assert_eq!(cal2.tasks[1].source, ModelSource::SummaryStream);
    assert_eq!(cal2.tasks[2].source, ModelSource::SummaryBurst);
    assert!(report2.max_rel_err.unwrap() <= TOL, "{:?}", report2.per_task);
}

/// Calibration is robust to a trace of a *jittered* run: the model fitted
/// from a noisy execution still replays that execution closely (the noise
/// is baked into the observed trajectory, and the fit follows it).
#[test]
fn jittered_run_still_replays() {
    let wf = chain();
    let run = execute(
        &wf,
        &FluidOpts {
            dt: 0.005,
            sample_every: 0.1,
            jitter: Some((7, 0.02)),
            ..FluidOpts::default()
        },
    );
    let (tsv_trace, series) = export_trace(&wf, &run).expect("export");
    let (_, report) = calibrate_trace(
        &write_tsv(&tsv_trace),
        Some(&write_io_log(&series)),
        &CalibrateOpts::default(),
        &SolverOpts::default(),
    )
    .expect("calibrate");
    // noise widens the bound a little, but the replay must stay close
    assert!(report.max_rel_err.unwrap() < 0.05, "{:?}", report.per_task);
}
