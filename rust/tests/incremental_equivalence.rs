//! Incremental-engine equivalence suite: the analysis cache must be purely
//! an accelerator. For any perturbation batch, cold (uncached, sequential),
//! warm (cached, sequential) and parallel-warm (cached, N threads) runs
//! must produce **bit-for-bit identical** per-scenario analyses and ranked
//! reports; the dirty-set oracle must over-approximate nothing the cache
//! relies on (a clean node must hit once its entry exists).

use std::sync::Arc;

use bottlemod::runtime::cache::AnalysisCache;
use bottlemod::runtime::sweep::SweepBatch;
use bottlemod::util::rng::Rng;
use bottlemod::workflow::scenario::{Perturbation, VideoScenario};

/// A randomized batch mixing every perturbation kind.
fn random_batch(seed: u64, n: usize) -> Vec<Perturbation> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| match rng.below(8) {
            0 => Perturbation::Fraction(rng.range(0.05, 0.95)),
            1 => Perturbation::LinkRateScale(rng.range(0.5, 2.0)),
            2 => Perturbation::InputScale(rng.range(0.5, 4.0)),
            3 => Perturbation::CpuScale(rng.range(0.5, 2.0)),
            4 => Perturbation::Task1CpuScale(rng.range(0.5, 2.0)),
            5 => Perturbation::Task2TimeScale(rng.range(0.5, 2.0)),
            6 => Perturbation::Task3TimeScale(rng.range(0.5, 2.0)),
            _ => Perturbation::Task2Burst,
        })
        .collect()
}

/// cold == warm == parallel-warm on randomized batches, several seeds.
#[test]
fn cold_warm_parallel_bitwise_equal_randomized() {
    for seed in [7u64, 42, 2026] {
        let base = Arc::new(VideoScenario::default());
        let batch = random_batch(seed, 24);

        let (cold, cold_rep) = SweepBatch::new(base.clone())
            .with_threads(1)
            .run_report(&batch)
            .expect("cold run");
        let (warm, warm_rep) = SweepBatch::new(base.clone())
            .with_threads(1)
            .with_new_cache()
            .run_report(&batch)
            .expect("warm run");
        let (pwarm, pwarm_rep) = SweepBatch::new(base.clone())
            .with_threads(4)
            .with_new_cache()
            .run_report(&batch)
            .expect("parallel warm run");

        assert_eq!(cold, warm, "seed {seed}: warm != cold");
        assert_eq!(cold, pwarm, "seed {seed}: parallel warm != cold");
        assert_eq!(cold_rep.ranked, warm_rep.ranked, "seed {seed}");
        assert_eq!(cold_rep.ranked, pwarm_rep.ranked, "seed {seed}");
        assert_eq!(cold_rep.total_events, warm_rep.total_events);
        // outcomes arrive in batch order with their perturbations intact
        for (i, o) in cold.iter().enumerate() {
            assert_eq!(o.index, i);
            assert_eq!(o.perturbation, batch[i]);
        }
    }
}

/// A cache shared across *consecutive batches* keeps results identical and
/// answers the repeat batch almost entirely from memory.
#[test]
fn shared_cache_across_batches_is_transparent() {
    let base = Arc::new(VideoScenario::default());
    let batch = random_batch(99, 16);
    let cold = SweepBatch::new(base.clone())
        .with_threads(1)
        .run(&batch)
        .expect("cold");

    let cache = Arc::new(AnalysisCache::new());
    let sweep = SweepBatch::new(base.clone())
        .with_threads(2)
        .with_cache(cache.clone());
    let first = sweep.run(&batch).expect("first warm");
    assert_eq!(cold, first);

    cache.reset_counters();
    let second = sweep.run(&batch).expect("second warm");
    assert_eq!(cold, second);
    let s = cache.stats();
    assert_eq!(s.misses, 0, "identical repeat batch must be all hits: {s:?}");
    assert!(s.hits > 0);
}

/// Dirty-set oracle vs the cache: in a batch perturbing only task 3, the
/// clean nodes (both downloads, tasks 1-2) must be served from the cache
/// after the first scenario — the observable form of "only the downstream
/// cone of each perturbation is re-solved".
#[test]
fn clean_prefix_hits_after_first_scenario() {
    let base = Arc::new(VideoScenario::default());
    let (wf, nodes) = base.build();
    let dirty = Perturbation::Task3TimeScale(1.5).dirty_set(&wf, &nodes);
    assert_eq!(dirty.iter().collect::<Vec<_>>(), vec![nodes.task3]);

    let cache = Arc::new(AnalysisCache::new());
    let sweep = SweepBatch::new(base.clone())
        .with_threads(1)
        .with_cache(cache.clone());

    // scenario 0 populates the cache: every node misses at least once
    // (later fixpoint passes may already hit pass-1 entries)
    sweep
        .run(&[Perturbation::Task3TimeScale(1.0 + 1.0 / 64.0)])
        .expect("warm-up");
    let warmup = cache.stats();
    assert!(
        warmup.misses >= wf.nodes.len() as u64,
        "cold cache: every node solves once: {warmup:?}"
    );

    // every further scenario only misses on its dirty cone ({task3})
    cache.reset_counters();
    let n_more = 8usize;
    let batch: Vec<Perturbation> = (0..n_more)
        .map(|i| Perturbation::Task3TimeScale(1.5 + i as f64 / 16.0))
        .collect();
    sweep.run(&batch).expect("incremental batch");
    let s = cache.stats();
    let lookups = s.hits + s.misses;
    // per scenario and pass, exactly one node (task3) may miss
    let passes = lookups / (n_more as u64 * wf.nodes.len() as u64);
    assert!(passes >= 1, "at least one pass per scenario: {s:?}");
    assert!(
        s.misses <= n_more as u64 * passes.max(2),
        "only the dirty cone may miss: {s:?}"
    );
    assert!(
        s.hit_rate() >= 0.5,
        "single-node batch must be mostly cache hits: {s}"
    );
}

/// Per-variant dirty sets drive real reuse: the smaller the dirty set, the
/// fewer misses a fresh batch of that variant incurs.
#[test]
fn smaller_dirty_sets_miss_less() {
    let misses_for = |mk: &dyn Fn(usize) -> Perturbation| -> u64 {
        let base = Arc::new(VideoScenario::default());
        let cache = Arc::new(AnalysisCache::new());
        let batch: Vec<Perturbation> = (0..10usize).map(mk).collect();
        SweepBatch::new(base)
            .with_threads(1)
            .with_cache(cache.clone())
            .run(&batch)
            .expect("batch");
        cache.stats().misses
    };
    // whole-graph dirty: fractions (pool coupling dirties everything)
    let frac = misses_for(&|i| Perturbation::Fraction(0.2 + 0.06 * i as f64));
    // two-node dirty cone
    let t1 = misses_for(&|i| Perturbation::Task1CpuScale(0.5 + 0.1 * i as f64));
    // single-node dirty cone
    let t3 = misses_for(&|i| Perturbation::Task3TimeScale(0.5 + 0.1 * i as f64));
    assert!(
        t3 < t1 && t1 < frac,
        "miss counts should track dirty-set size: t3={t3} t1={t1} frac={frac}"
    );
}
