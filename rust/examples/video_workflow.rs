//! The paper's full evaluation workflow (Fig 5): two rate-capped downloads
//! feeding a reverse task and a rotate task, muxed by a third task.
//! Reproduces the Fig 7 sweep and the Fig 8 detail cases, and validates the
//! predictions against the virtual testbed.
//!
//! Run: `cargo run --release --example video_workflow`

use bottlemod::coordinator::sweeper::{best_fraction, exact_sweep, fig7_fractions};
use bottlemod::solver::SolverOpts;
use bottlemod::testbed::video::VideoTestbed;
use bottlemod::util::stats::{ascii_table, Summary};
use bottlemod::workflow::engine::analyze_fixpoint;
use bottlemod::workflow::scenario::VideoScenario;

fn main() -> bottlemod::util::error::Result<()> {
    // ---- Fig 8-style detail at two prioritizations ----------------------
    for f in [0.5, 0.95] {
        let sc = VideoScenario::default().with_fraction(f);
        let (wf, _) = sc.build();
        let wa = analyze_fixpoint(&wf, &SolverOpts::default(), 6)?;
        println!("== fraction {f} -> predicted total {:.1} s ==", wa.makespan.unwrap());
        for (i, a) in wa.analyses.iter().enumerate() {
            let p = &wf.nodes[i].process;
            let segs: Vec<String> = a
                .segments
                .iter()
                .map(|s| {
                    format!(
                        "[{:.0}-{:.0}s {}]",
                        s.start,
                        s.end.min(9999.0),
                        a.bottleneck_name(p, s.bottleneck)
                    )
                })
                .collect();
            println!(
                "  {:14} finish {:7.1} s   {}",
                p.name,
                a.finish_time.unwrap_or(f64::NAN),
                segs.join(" ")
            );
        }
    }

    // ---- Fig 7: 600-point sweep + testbed validation --------------------
    let sc = VideoScenario::default();
    let threads = std::thread::available_parallelism()?.get();
    let sweep = exact_sweep(&sc, &fig7_fractions(600), threads);
    let (best_f, best_t) = best_fraction(&sweep);
    let t50 = sweep
        .fractions
        .iter()
        .zip(&sweep.totals)
        .min_by(|a, b| (a.0 - 0.5).abs().partial_cmp(&(b.0 - 0.5).abs()).unwrap())
        .map(|(_, t)| *t)
        .unwrap();
    println!("\n== Fig 7 sweep (600 prioritizations) ==");
    println!("best fraction {best_f:.3}: {best_t:.1} s; 50:50: {t50:.1} s");
    println!(
        "headline: {:.1}% shorter with >=93% than 50:50 (paper: 32%)",
        (1.0 - best_t / t50) * 100.0
    );

    // measured bars at a few fractions, 10 jittered runs each
    let mut rows = vec![vec![
        "fraction".into(),
        "predicted".into(),
        "measured mean".into(),
        "min".into(),
        "max".into(),
    ]];
    for f in [0.25, 0.5, 0.75, 0.93, 0.95] {
        let idx = sweep
            .fractions
            .iter()
            .enumerate()
            .min_by(|a, b| (a.1 - f).abs().partial_cmp(&(b.1 - f).abs()).unwrap())
            .unwrap()
            .0;
        let tb = VideoTestbed::new(sc.clone().with_fraction(f));
        let runs = tb.measure(10, 7 + (f * 100.0) as u64, 0.01);
        let s = Summary::of(&runs);
        rows.push(vec![
            format!("{f:.2}"),
            format!("{:.1}", sweep.totals[idx]),
            format!("{:.1}", s.mean),
            format!("{:.1}", s.min),
            format!("{:.1}", s.max),
        ]);
    }
    print!("{}", ascii_table(&rows));
    Ok(())
}
