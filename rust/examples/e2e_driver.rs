//! End-to-end driver: proves all layers compose on the real workload.
//!
//! 1. loads the Fig 5 workflow from a JSON spec (`examples/specs/video.json`)
//!    and analyzes it with the exact L3 engine (Algorithm 2);
//! 2. "executes" the workflow on the virtual testbed (byte-accurate,
//!    jittered) — the measured ground truth;
//! 3. runs the Fig 7 sweep twice: exact engine across threads AND the
//!    batched L2/L1 path (PJRT executing the AOT-compiled JAX `grid_solve`
//!    with the Pallas piecewise kernel lowered inside);
//! 4. cross-checks all numbers and prints the paper-vs-measured table.
//!
//! Run: `make artifacts && cargo run --release --example e2e_driver`
//! The headline results are recorded in EXPERIMENTS.md.

use std::time::Instant;

use bottlemod::coordinator::sweeper::{best_fraction, exact_sweep, fig7_fractions};
use bottlemod::model::spec::parse_workflow;
use bottlemod::runtime::{fig7_sweep, Runtime};
use bottlemod::solver::SolverOpts;
use bottlemod::testbed::video::VideoTestbed;
use bottlemod::util::stats::{ascii_table, fmt_duration, Summary};
use bottlemod::workflow::engine::analyze_fixpoint;
use bottlemod::workflow::scenario::VideoScenario;

fn main() -> bottlemod::util::error::Result<()> {
    let opts = SolverOpts::default();

    // ---- 1. spec -> exact analysis --------------------------------------
    let spec_path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("examples/specs/video.json");
    let spec = std::fs::read_to_string(&spec_path)?;
    let wf = parse_workflow(&spec)?;
    let t0 = Instant::now();
    let wa = analyze_fixpoint(&wf, &opts, 6)?;
    let analysis_dt = t0.elapsed().as_secs_f64();
    let predicted_50 = wa.makespan.unwrap();
    println!(
        "[1] spec analysis (50:50): {predicted_50:.1} s predicted, {} per analysis, {} events",
        fmt_duration(analysis_dt),
        wa.events
    );

    // ---- 2. virtual testbed execution -----------------------------------
    let sc = VideoScenario::default();
    let tb = VideoTestbed::new(sc.clone().with_fraction(0.5));
    let runs = tb.measure(10, 99, 0.01);
    let meas = Summary::of(&runs);
    println!(
        "[2] testbed (10 jittered runs): mean {:.1} s (min {:.1}, max {:.1}) — prediction error {:+.1}%",
        meas.mean,
        meas.min,
        meas.max,
        (predicted_50 / meas.mean - 1.0) * 100.0
    );
    bottlemod::ensure!(
        (predicted_50 - meas.mean).abs() < 0.03 * meas.mean,
        "prediction diverges from testbed"
    );

    // ---- 3a. exact sweep --------------------------------------------------
    let threads = bottlemod::util::par::num_threads();
    let fractions = fig7_fractions(600);
    let t0 = Instant::now();
    let sweep = exact_sweep(&sc, &fractions, threads);
    let exact_dt = t0.elapsed().as_secs_f64();
    let (best_f, best_t) = best_fraction(&sweep);
    println!(
        "[3a] exact sweep: 600 configs in {} ({threads} threads); best fraction {best_f:.3} -> {best_t:.1} s",
        fmt_duration(exact_dt)
    );

    // ---- 3b. batched PJRT sweep (L2 grid solver + L1 Pallas kernel) -----
    // only meaningful in builds with the XLA backend; offline, skip it
    // exactly like the benches and integration tests do
    if Runtime::backend_available() {
        let mut rt = Runtime::new(&Runtime::default_dir())?;
        let t0 = Instant::now();
        let batched = fig7_sweep(&mut rt, &sc, &fractions)?;
        let pjrt_dt = t0.elapsed().as_secs_f64();
        let max_err = sweep
            .totals
            .iter()
            .zip(&batched.totals)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        println!(
            "[3b] PJRT batched sweep: 600 configs in {} (7 artifact executions); max |Δ| vs exact {max_err:.2} s",
            fmt_duration(pjrt_dt)
        );
        bottlemod::ensure!(max_err < 5.0, "batched sweep diverged from exact engine");
    } else {
        println!("[3b] PJRT batched sweep skipped: no execution backend in this build");
    }

    // ---- 4. the paper-vs-measured table ----------------------------------
    let t50 = nearest(&sweep.fractions, &sweep.totals, 0.5);
    let t93 = nearest(&sweep.fractions, &sweep.totals, 0.93);
    let gain = (1.0 - t93 / t50) * 100.0;
    let rows = vec![
        vec![
            "quantity".into(),
            "paper".into(),
            "this repo".into(),
        ],
        vec![
            "total @50:50 (s)".into(),
            "(Fig 7 ~263)".into(),
            format!("{t50:.1} predicted / {:.1} measured", meas.mean),
        ],
        vec![
            "gain of >=93% vs 50:50".into(),
            "32%".into(),
            format!("{gain:.1}%"),
        ],
        vec![
            "optimal fraction".into(),
            ">=0.93".into(),
            format!("{best_f:.3}"),
        ],
        vec![
            "analysis cost".into(),
            "20.0 ms (python)".into(),
            fmt_duration(analysis_dt),
        ],
    ];
    println!("\n{}", ascii_table(&rows));
    bottlemod::ensure!((28.0..36.0).contains(&gain), "headline gain out of range");
    println!("e2e driver OK — all three layers agree");
    Ok(())
}

fn nearest(fr: &[f64], totals: &[f64], target: f64) -> f64 {
    fr.iter()
        .zip(totals)
        .min_by(|a, b| {
            (a.0 - target)
                .abs()
                .partial_cmp(&(b.0 - target).abs())
                .unwrap()
        })
        .map(|(_, t)| *t)
        .unwrap()
}
