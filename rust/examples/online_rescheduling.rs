//! Online re-analysis demo: a controller re-runs BottleMod on live state
//! every few seconds and re-splits the shared link (paper §7: the analysis
//! is fast enough to run "while the tasks or the workflow is still
//! executing").
//!
//! Run: `cargo run --release --example online_rescheduling`

use bottlemod::sched::{run_online, LiveState};
use bottlemod::util::stats::fmt_duration;
use bottlemod::workflow::scenario::VideoScenario;

fn main() -> bottlemod::util::error::Result<()> {
    let sc = VideoScenario::default();

    // baseline: fair share, never replanned
    let fair = run_online(&sc, 1e12, &[0.5]);
    println!("static fair share total: {:.1} s", fair.total);

    // the controller: 19 candidate splits, replanned every 10 simulated s
    let candidates: Vec<f64> = (1..=19).map(|i| i as f64 / 20.0).collect();
    for period in [30.0, 10.0, 5.0] {
        let r = run_online(&sc, period, &candidates);
        println!(
            "replan every {:>4.0} s: total {:.1} s ({:+.1}% vs fair), {} decisions, model overhead {}",
            period,
            r.total,
            (r.total / fair.total - 1.0) * 100.0,
            r.decisions.len(),
            fmt_duration(r.analysis_seconds),
        );
    }

    // a single mid-flight prediction, as a scheduler would issue it
    let st = LiveState {
        d1: 300e6,
        d2: 300e6,
        t1_out: 0.0,
        t2_out: 250e6,
    };
    let t0 = std::time::Instant::now();
    let pred = bottlemod::sched::predict_remaining(&sc, &st, 0.9);
    println!(
        "\nmid-flight query: predicted remaining time at fraction 0.9 = {:.1} s (answered in {})",
        pred,
        fmt_duration(t0.elapsed().as_secs_f64())
    );
    Ok(())
}
