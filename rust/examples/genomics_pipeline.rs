//! A genomics-flavoured workflow (the paper's intro motivates genome
//! analysis): a sequencer dump is downloaded, QC-filtered (stream), aligned
//! (burst per sample — the aligner builds an index over the full sample
//! first), and the variants are called from all alignments (burst join).
//! Two samples share the ingest link; alignment shares a CPU pool.
//!
//! Demonstrates: a larger DAG (8 processes), two shared pools, bottleneck
//! reporting across the whole workflow, and the advisor primitive on a
//! non-video scenario. The model itself lives in the library
//! (`workflow::scenario::GenomicsScenario`) and is also exercised by the
//! conformance test suite.
//!
//! Run: `cargo run --release --example genomics_pipeline`

use bottlemod::solver::SolverOpts;
use bottlemod::util::stats::ascii_table;
use bottlemod::workflow::engine::analyze_fixpoint;
use bottlemod::workflow::scenario::GenomicsScenario;

fn main() -> bottlemod::util::error::Result<()> {
    let opts = SolverOpts::default();

    // fair ingest split
    let wf = GenomicsScenario::default().build();
    let wa = analyze_fixpoint(&wf, &opts, 6)?;
    println!("== genomics pipeline, fair ingest split ==");
    let mut rows = vec![vec![
        "process".into(),
        "start (s)".into(),
        "finish (s)".into(),
        "dominant bottleneck".into(),
    ]];
    for (i, a) in wa.analyses.iter().enumerate() {
        let p = &wf.nodes[i].process;
        // dominant = longest segment
        let dom = a
            .segments
            .iter()
            .max_by(|x, y| {
                (x.end - x.start).partial_cmp(&(y.end - y.start)).unwrap()
            })
            .map(|s| a.bottleneck_name(p, s.bottleneck))
            .unwrap_or_default();
        rows.push(vec![
            p.name.clone(),
            format!("{:.0}", a.start_time),
            format!("{:.0}", a.finish_time.unwrap_or(f64::NAN)),
            dom,
        ]);
    }
    print!("{}", ascii_table(&rows));
    println!("makespan: {:.0} s  ({} solver events)", wa.makespan.unwrap(), wa.events);

    // sweep the ingest split like the paper sweeps the link
    println!("\n== ingest-split sweep ==");
    let mut best = (0.5, f64::INFINITY);
    for i in 1..20 {
        let f = i as f64 / 20.0;
        let wf = GenomicsScenario::default().with_fraction(f).build();
        let total = analyze_fixpoint(&wf, &opts, 6)?.makespan.unwrap();
        if total < best.1 {
            best = (f, total);
        }
    }
    let fair = wa.makespan.unwrap();
    println!(
        "best split {:.2} -> {:.0} s vs fair {:.0} s ({:+.1}%)",
        best.0,
        best.1,
        fair,
        (best.1 / fair - 1.0) * 100.0
    );
    Ok(())
}
