//! Trace-driven modeling — the paper's future work (§5.2/§8) realized by
//! the `trace` subsystem, end to end:
//!
//! 1. execute the Fig 5 workflow on the fluid testbed with the BPF-style
//!    I/O recorder on (the ground truth a real cluster would log);
//! 2. export the run in the raw trace formats (Nextflow-style TSV +
//!    cumulative I/O series — `docs/TRACES.md`) and parse them back,
//!    exactly as `bottlemod calibrate <trace.tsv> --io <series.log>` would;
//! 3. calibrate requirement functions per task, assemble the workflow from
//!    the trace's dependency edges, and replay it through the analytic
//!    solver;
//! 4. report per-task predicted-vs-observed completion error (≤ 2 %), and
//!    compare with the paper's hand-built model.
//!
//! Bundled fixtures for the CLI live in `rust/examples/traces/`.
//!
//! Run: `cargo run --release --example trace_fitting`

use bottlemod::solver::SolverOpts;
use bottlemod::testbed::fluid::{execute, export_trace, FluidOpts};
use bottlemod::trace::{calibrate_trace, write_io_log, write_tsv, CalibrateOpts};
use bottlemod::util::stats::ascii_table;
use bottlemod::workflow::engine::analyze_fixpoint;
use bottlemod::workflow::scenario::VideoScenario;

fn main() -> bottlemod::util::error::Result<()> {
    let sc = VideoScenario::default();
    let (wf, _) = sc.build();

    // ---- 1. run the workflow with the I/O recorder on --------------------
    let run = execute(
        &wf,
        &FluidOpts {
            dt: 0.02,
            sample_every: 0.5,
            ..FluidOpts::default()
        },
    );
    let measured = run
        .makespan
        .ok_or_else(|| bottlemod::util::error::Error::msg("fluid run never finished"))?;

    // ---- 2. export as raw trace text, parse back -------------------------
    let (tsv_trace, series) = export_trace(&wf, &run)?;
    let tsv = write_tsv(&tsv_trace);
    let io_log = write_io_log(&series);
    println!(
        "exported trace: {} TSV rows, {} I/O samples ({} KiB total)",
        tsv_trace.tasks.len(),
        series.iter().map(|s| s.ts.len()).sum::<usize>(),
        (tsv.len() + io_log.len()) / 1024
    );

    // ---- 3. calibrate + assemble + replay --------------------------------
    let (cal, report) = calibrate_trace(
        &tsv,
        Some(&io_log),
        &CalibrateOpts::default(),
        &SolverOpts::default(),
    )?;

    let mut rows = vec![vec![
        "task".into(),
        "model".into(),
        "R_D/R_R pieces".into(),
        "observed".into(),
        "predicted".into(),
        "err %".into(),
    ]];
    for s in cal.task_summaries(&report) {
        rows.push(vec![
            s.id,
            s.model,
            format!("{}/{}", s.data_pieces, s.res_pieces),
            format!("{:.1} s", s.observed.unwrap_or(f64::NAN)),
            format!("{:.1} s", s.predicted.unwrap_or(f64::NAN)),
            format!("{:.2}", s.rel_err.unwrap_or(f64::NAN) * 100.0),
        ]);
    }
    print!("{}", ascii_table(&rows));

    // ---- 4. acceptance: calibrated model ≈ reality ≈ hand model ----------
    let hand = analyze_fixpoint(&wf, &SolverOpts::default(), 6)?
        .makespan
        .unwrap();
    let calibrated = report.predicted_makespan.unwrap();
    println!(
        "makespan — testbed {measured:.1} s, calibrated model {calibrated:.1} s, \
         hand model {hand:.1} s"
    );
    let worst = report.max_rel_err.unwrap();
    println!("worst per-task completion error: {:.2}%", worst * 100.0);
    bottlemod::ensure!(worst < 0.02, "calibrated model diverged: {worst}");
    println!("trace calibration OK — models learned from logs replay the workflow");
    Ok(())
}
