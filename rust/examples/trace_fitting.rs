//! Trace-driven modeling — the paper's future work (§5.2/§8) realized:
//! record BPF-style I/O traces of isolated task executions, *fit* the
//! requirement functions from the logs, assemble the workflow model from
//! the fitted processes, and verify the predictions against the testbed.
//!
//! The fitted task 1 model is strictly richer than the paper's hand model:
//! the 26 s of read+decode CPU shows up in the log as up-front resource
//! demand and is replayed by the solver as work that overlaps the download.
//!
//! Run: `cargo run --release --example trace_fitting`

use bottlemod::model::fit::{fit_process, FitOpts};
use bottlemod::model::ProcessBuilder;
use bottlemod::pwfn::PwPoly;
use bottlemod::solver::SolverOpts;
use bottlemod::testbed::video::VideoTestbed;
use bottlemod::util::stats::ascii_table;
use bottlemod::workflow::engine::analyze_fixpoint;
use bottlemod::workflow::graph::{DataSource, ResourceSource, StartRule, Workflow};
use bottlemod::workflow::scenario::VideoScenario;

fn main() -> bottlemod::util::error::Result<()> {
    let sc = VideoScenario::default();

    // ---- 1. record isolated executions (the paper's BPF monitoring) -----
    let mut tb = VideoTestbed::new(sc.clone());
    tb.sample_every = 0.25;
    let trace1 = tb.isolated_task1();
    tb.sample_every = 0.05;
    let trace2 = tb.isolated_task2();
    println!(
        "recorded {} + {} samples from isolated runs of task 1 / task 2",
        trace1.ts.len(),
        trace2.ts.len()
    );

    // ---- 2. fit requirement functions from the logs ----------------------
    let opts = FitOpts::default();
    let t1 = fit_process("task1-fitted", &trace1, 1.0, &opts);
    let t2 = fit_process("task2-fitted", &trace2, 1.0, &opts);
    for p in [&t1, &t2] {
        println!(
            "{}: R_D with {} piece(s), R_R with {} piece(s), max_progress {:.1} MB",
            p.name,
            p.data_reqs[0].func.n_pieces(),
            p.res_reqs[0].func.n_pieces(),
            p.max_progress / 1e6
        );
        p.validate()?;
    }

    // ---- 3. assemble the workflow from fitted processes ------------------
    let build_fitted = |fraction: f64| {
        let mut wf = Workflow::new();
        let pool = wf.add_pool("link", PwPoly::constant(sc.link_rate));
        let dl = |name: &str| {
            ProcessBuilder::new(name, sc.input_size)
                .stream_data("remote", sc.input_size)
                .stream_resource("link", sc.input_size)
                .identity_output("file")
                .build()
        };
        let d1 = wf.add_node(
            dl("dl1"),
            vec![DataSource::External(PwPoly::constant(sc.input_size))],
            vec![ResourceSource::PoolFraction { pool, fraction }],
            StartRule::default(),
        );
        let d2 = wf.add_node(
            dl("dl2"),
            vec![DataSource::External(PwPoly::constant(sc.input_size))],
            vec![ResourceSource::PoolResidual { pool }],
            StartRule::default(),
        );
        let n1 = wf.add_node(
            t1.clone(),
            vec![DataSource::ProcessOutput { node: d1, output: 0 }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );
        let n2 = wf.add_node(
            t2.clone(),
            vec![DataSource::ProcessOutput { node: d2, output: 0 }],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule::default(),
        );
        let t3_total = t1.max_progress + t2.max_progress;
        let t3 = ProcessBuilder::new("task3", t3_total)
            .stream_resource("io", sc.t3_time)
            .identity_output("result")
            .build();
        wf.add_node(
            t3,
            vec![],
            vec![ResourceSource::Fixed(PwPoly::constant(1.0))],
            StartRule {
                at: 0.0,
                after: vec![n1, n2],
            },
        );
        wf
    };

    // ---- 4. predict vs testbed across fractions --------------------------
    let mut rows = vec![vec![
        "fraction".into(),
        "fitted-model prediction".into(),
        "hand-model prediction".into(),
        "testbed measured".into(),
    ]];
    let sopts = SolverOpts::default();
    let mut worst = 0.0f64;
    for f in [0.3, 0.5, 0.75, 0.93] {
        let fitted = analyze_fixpoint(&build_fitted(f), &sopts, 6)?
            .makespan
            .unwrap();
        let (hand_wf, _) = sc.clone().with_fraction(f).build();
        let hand = analyze_fixpoint(&hand_wf, &sopts, 6)?.makespan.unwrap();
        let measured = VideoTestbed::new(sc.clone().with_fraction(f)).run(None).total;
        worst = worst.max((fitted - measured).abs() / measured);
        rows.push(vec![
            format!("{f:.2}"),
            format!("{fitted:.1} s"),
            format!("{hand:.1} s"),
            format!("{measured:.1} s"),
        ]);
    }
    print!("{}", ascii_table(&rows));
    println!("worst fitted-model error vs testbed: {:.2}%", worst * 100.0);
    bottlemod::ensure!(worst < 0.02, "fitted model diverged");
    println!("trace fitting OK — models learned from logs predict the workflow");
    Ok(())
}
