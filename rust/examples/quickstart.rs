//! Quickstart: model one task, derive its progress function and bottleneck
//! timeline (paper §2–§3 in ~40 lines of API).
//!
//! Run: `cargo run --release --example quickstart`

use bottlemod::model::{ProcessBuilder, ProcessInputs};
use bottlemod::pwfn::PwPoly;
use bottlemod::solver::{solve, SolverOpts};

fn main() -> bottlemod::util::error::Result<()> {
    // A video re-encode: stream-type data requirement (progress with every
    // byte read, Fig 1a), CPU spread evenly over the output (Fig 1b).
    let process = ProcessBuilder::new("reencode", 100e6) // 100 MB of output
        .stream_data("video-in", 500e6) // needs 500 MB of input overall
        .stream_resource("cpu", 60.0) // 60 CPU-seconds overall
        .identity_output("video-out")
        .build();

    // Execution side: the input arrives from a 10 MB/s source; one core.
    let inputs = ProcessInputs {
        data: vec![PwPoly::ramp_to(0.0, 10e6, 500e6)],
        resources: vec![PwPoly::constant(1.0)],
        start_time: 0.0,
    };

    let analysis = solve(&process, &inputs, &SolverOpts::default())?;

    println!("finish time: {:.1} s", analysis.finish_time.unwrap());
    println!("progress at t=10 s: {:.1} MB", analysis.progress.eval(10.0) / 1e6);
    println!("\nbottleneck timeline:");
    for seg in &analysis.segments {
        println!(
            "  {:6.1} .. {:6.1} s  limited by {}",
            seg.start,
            seg.end.min(1e9),
            analysis.bottleneck_name(&process, seg.bottleneck)
        );
    }

    // §3.3 extras: how much of the CPU allocation is actually used, and how
    // much input sits unread in the buffer, sampled at a few times.
    let ts = [5.0, 20.0, 40.0];
    let usage = analysis.relative_usage_sampled(&process, &inputs, 0, &ts);
    let buffered = analysis.buffered_data_sampled(&process, &inputs, 0, &ts);
    println!("\n   t      cpu-usage   buffered input");
    for (i, t) in ts.iter().enumerate() {
        println!(
            "  {:4.0} s     {:4.0} %     {:7.1} MB",
            t,
            usage[i] * 100.0,
            buffered[i] / 1e6
        );
    }
    Ok(())
}
