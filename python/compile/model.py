"""Layer-2 JAX model: the batched grid solver.

A discretized, batched rendition of the paper's generic Algorithm 1: given
B independent process configurations, a ``lax.scan`` imposes the resource
speed limits ``P'(t) <= min_l I_Rl(t) / R'_Rl(P(t))`` step by step on a
shared time grid and caps progress by the data envelope ``P_D``. Two entry
points:

* :func:`grid_solve` — takes the data-progress functions as *piecewise
  polynomials* and evaluates them through the Layer-1 Pallas kernel
  (`kernels/pwpoly_eval.py`), so the kernel lowers into this HLO;
* :func:`grid_solve_pd` — takes pre-sampled ``P_D`` grids [B, K, T]
  (used by the Rust coordinator for chained workflow stages, where a
  predecessor's progress grid feeds the successor's data envelope).

Both return the progress grids P [B, T] and per-config makespans [B]
(time of first reaching ``target``; +inf when unreached in the grid).

Semantics notes (mirroring `rust/src/solver/grid.rs`):
* resource requirements must be piecewise-linear (R' piecewise-constant) —
  the §4 restriction; jumps in R (burst resources) are not supported here;
* a resource with R' = 0 (padding) never limits;
* the scan is forward Euler: makespans carry O(dt) discretization error.
"""

import jax
import jax.numpy as jnp

from .kernels.pwpoly_eval import pwpoly_eval, pwpoly_eval_math


def _cost_lookup(p, rbreaks, rslopes):
    """R'_Rl(p) lookup. p: [B] -> [B, L] piecewise-constant values."""
    S2 = rslopes.shape[-1]
    inner = rbreaks[..., 1:S2]  # [B, L, S2-1]
    idx = jnp.sum(
        (p[:, None, None] >= inner).astype(jnp.int32), axis=-1
    )  # [B, L]
    onehot = (idx[..., None] == jnp.arange(S2)[None, None, :]).astype(p.dtype)
    return jnp.sum(onehot * rslopes, axis=-1)


def _scan_solver(pdmin, rbreaks, rslopes, rin, ts, target):
    """Forward-Euler scan. pdmin: [B, T] -> (P [B, T], makespan [B])."""
    dt = ts[1] - ts[0]

    def step(p, xs):
        pd_next, rin_t = xs  # [B], [B, L]
        c = _cost_lookup(p, rbreaks, rslopes)
        limited = c > 1e-20
        speed = jnp.where(limited, rin_t / jnp.maximum(c, 1e-20), jnp.inf)
        dp = dt * jnp.min(speed, axis=-1)
        nxt = jnp.minimum(pd_next, p + jnp.maximum(dp, 0.0))
        nxt = jnp.maximum(nxt, p)  # monotone
        return nxt, nxt

    p0 = jnp.maximum(jnp.minimum(pdmin[:, 0], 0.0), 0.0)  # zeros, typed
    xs = (pdmin[:, 1:].T, jnp.moveaxis(rin, 2, 0)[:-1])
    # NOTE(§Perf): unroll={2,8} was tried and *hurt* on CPU PJRT (80/110 ms
    # vs 67 ms for the 600x2048 stage) — the compact loop body wins; see
    # EXPERIMENTS.md §Perf for the iteration log.
    _, hist = jax.lax.scan(step, p0, xs)
    P = jnp.concatenate([p0[:, None], hist.T], axis=1)  # [B, T]
    reached = P >= target[:, None] * (1.0 - 1e-6)
    any_reached = reached.any(axis=1)
    idx = jnp.argmax(reached, axis=1)
    makespan = jnp.where(any_reached, ts[idx], jnp.inf)
    return P, makespan


def grid_solve_pd(pd, rbreaks, rslopes, rin, ts, target):
    """Solve from pre-sampled data-progress grids.

    pd: [B, K, T]; rbreaks: [B, L, S2+1]; rslopes: [B, L, S2];
    rin: [B, L, T]; ts: [T]; target: [B].
    """
    pdmin = jnp.min(pd, axis=1)
    return _scan_solver(pdmin, rbreaks, rslopes, rin, ts, target)


def grid_solve(breaks_d, coeffs_d, rbreaks, rslopes, rin, ts, target):
    """Solve from piecewise data-progress functions (Pallas-kernel path).

    breaks_d: [B, K, S+1]; coeffs_d: [B, K, S, D]; rest as grid_solve_pd.
    """
    B, K = breaks_d.shape[0], breaks_d.shape[1]
    S, D = coeffs_d.shape[2], coeffs_d.shape[3]
    pd = pwpoly_eval(
        breaks_d.reshape(B * K, S + 1),
        coeffs_d.reshape(B * K, S, D),
        ts,
    ).reshape(B, K, ts.shape[0])
    return grid_solve_pd(pd, rbreaks, rslopes, rin, ts, target)


def resource_usage_grid(P, rbreaks, rslopes, ts):
    """§3.3 resource demand on the grid: P'(t) · R'(P(t)).

    P: [B, T] -> [B, L, T] (first column zero-padded).
    """
    dt = ts[1] - ts[0]
    dp = jnp.diff(P, axis=1) / dt  # [B, T-1]
    # cost at the left endpoint of each step
    B, T = P.shape
    flatP = P[:, :-1].reshape(-1)
    # lookup per (b, t): reuse pwpoly machinery by treating p as "time"
    S2 = rslopes.shape[-1]
    inner = rbreaks[..., 1:S2]  # [B, L, S2-1]
    idx = jnp.sum(
        (P[:, None, :-1, None] >= inner[:, :, None, :]).astype(jnp.int32),
        axis=-1,
    )  # [B, L, T-1]
    onehot = (idx[..., None] == jnp.arange(S2)[None, None, None, :]).astype(
        P.dtype
    )
    cost = jnp.sum(onehot * rslopes[:, :, None, :], axis=-1)  # [B, L, T-1]
    usage = cost * dp[:, None, :]
    _ = flatP
    return jnp.concatenate([jnp.zeros((B, cost.shape[1], 1), P.dtype), usage], axis=2)


def eval_pw(breaks, coeffs, ts):
    """Standalone batched piecewise evaluation (exported as its own
    artifact for the Rust coordinator's figure/grid exports). Runs through
    the Pallas kernel."""
    _ = pwpoly_eval_math  # shared math is exercised via the kernel body
    return pwpoly_eval(breaks, coeffs, ts)
