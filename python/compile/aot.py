"""AOT export: lower the L2/L1 JAX computations to HLO *text* artifacts.

HLO text — NOT ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (shapes baked in; the Rust runtime pads to them):

* ``eval_pw_b64_s16_d4_t1024``   — standalone Pallas piecewise evaluation
* ``grid_solve_b600_k2_s8_d4_l2_s4_t2048`` — Fig 7 sweep solver (kernel path)
* ``grid_solve_pd_b600_k2_l2_s4_t2048``    — chained-stage solver (PD grids)
* ``grid_solve_pd_b8_k2_l2_s4_t256``       — small test/CI variant

A ``manifest.json`` records entry names, input shapes and dtypes so the
Rust runtime can validate before executing.

Run: ``python -m compile.aot --out-dir ../artifacts`` (see Makefile).
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_entries():
    """(name, function, example-arg specs) for every artifact."""
    f32 = jnp.float32
    entries = []

    # standalone kernel artifact
    B, S, D, T = 64, 16, 4, 1024
    entries.append(
        (
            f"eval_pw_b{B}_s{S}_d{D}_t{T}",
            lambda breaks, coeffs, ts: (model.eval_pw(breaks, coeffs, ts),),
            [_spec((B, S + 1), f32), _spec((B, S, D), f32), _spec((T,), f32)],
        )
    )

    # sweep solver (kernel path): Fig 7's 600 prioritizations
    B, K, S, D, L, S2, T = 600, 2, 8, 4, 2, 4, 2048
    entries.append(
        (
            f"grid_solve_b{B}_k{K}_s{S}_d{D}_l{L}_s{S2}_t{T}",
            model.grid_solve,
            [
                _spec((B, K, S + 1), f32),
                _spec((B, K, S, D), f32),
                _spec((B, L, S2 + 1), f32),
                _spec((B, L, S2), f32),
                _spec((B, L, T), f32),
                _spec((T,), f32),
                _spec((B,), f32),
            ],
        )
    )

    # chained-stage solver (PD-grid path), sweep + small variants
    for B, K, L, S2, T in [(600, 2, 2, 4, 2048), (8, 2, 2, 4, 256)]:
        entries.append(
            (
                f"grid_solve_pd_b{B}_k{K}_l{L}_s{S2}_t{T}",
                model.grid_solve_pd,
                [
                    _spec((B, K, T), f32),
                    _spec((B, L, S2 + 1), f32),
                    _spec((B, L, S2), f32),
                    _spec((B, L, T), f32),
                    _spec((T,), f32),
                    _spec((B,), f32),
                ],
            )
        )
    return entries


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on entry names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, fn, specs in build_entries():
        if args.only and args.only not in name:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [list(s.shape) for s in specs],
            "dtype": "f32",
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
