"""Layer-1 Pallas kernel: batched piecewise-polynomial evaluation.

Evaluates B piecewise polynomials (the quasi-symbolic function objects of
BottleMod) on a shared time grid of T points. This is the compute hot-spot
of the batched grid solver (`python/compile/model.py`): data-progress
functions, resource-input functions and R' lookups are all piecewise
evaluations.

Representation (matching `rust/src/pwfn/piecewise.rs`):
  * ``breaks``  [B, S+1] — piece start points, strictly increasing; padded
    pieces use ``BIG`` (1e30) so they are never selected.
  * ``coeffs``  [B, S, D] — local-coordinate polynomial coefficients
    (lowest degree first): piece s evaluates ``sum_d c[s,d] * (t - start_s)^d``.
  * right-continuity and clamp-left semantics as in the Rust engine.

TPU shaping (see DESIGN.md §Hardware-Adaptation): the (B, T) output grid is
tiled by BlockSpec so one block's breakpoints + coefficients sit in VMEM;
piece selection is a data-parallel compare-and-sum (VPU), piece gathering is
a one-hot contraction (MXU-friendly einsum), and Horner evaluation unrolls
into a fused multiply-add chain over the static D axis. ``interpret=True``
is mandatory on CPU PJRT — real TPU lowering emits a Mosaic custom-call the
CPU plugin cannot execute.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# padding sentinel for unused pieces/breaks
BIG = 1e30


def pwpoly_eval_math(breaks, coeffs, ts):
    """Shared evaluation math (used by the kernel body and by model.py's
    in-scan lookups).

    breaks: [b, S+1], coeffs: [b, S, D], ts: [T]  ->  [b, T]
    """
    S = coeffs.shape[-2]
    starts = breaks[..., :S]            # [b, S]
    inner = breaks[..., 1:S]            # [b, S-1]
    t = ts[None, :]                     # [1, T]
    # right-continuous piece index: number of inner starts <= t
    idx = jnp.sum(
        (t[..., None] >= inner[:, None, :]).astype(jnp.int32), axis=-1
    )                                   # [b, T]
    onehot = (idx[..., None] == jnp.arange(S)[None, None, :]).astype(
        coeffs.dtype
    )                                   # [b, T, S]
    origin = jnp.einsum("bts,bs->bt", onehot, starts)
    # clamp-left semantics: left of the domain the function is constant
    tc = jnp.maximum(t, starts[:, :1])
    u = tc - origin
    csel = jnp.einsum("bts,bsd->btd", onehot, coeffs)  # [b, T, D]
    # Horner over the static degree axis (unrolled FMA chain)
    acc = csel[..., -1]
    for d in range(coeffs.shape[-1] - 2, -1, -1):
        acc = acc * u + csel[..., d]
    return acc


def _pick_block(n, cap):
    """Largest divisor of n that is <= cap (VMEM-friendly tile size)."""
    best = 1
    for d in range(1, min(n, cap) + 1):
        if n % d == 0:
            best = d
    return best


def _kernel(breaks_ref, coeffs_ref, ts_ref, out_ref):
    out_ref[...] = pwpoly_eval_math(breaks_ref[...], coeffs_ref[...], ts_ref[...])


def pwpoly_eval(breaks, coeffs, ts, *, block_b=None, block_t=None, interpret=True):
    """Batched piecewise-polynomial evaluation as a Pallas call.

    breaks: [B, S+1], coeffs: [B, S, D], ts: [T]  ->  [B, T]

    B must be divisible by block_b and T by block_t (the AOT entry points
    pick compatible shapes; pad externally otherwise).
    """
    B, T = breaks.shape[0], ts.shape[0]
    S, D = coeffs.shape[1], coeffs.shape[2]
    block_b = block_b or _pick_block(B, 64)
    block_t = block_t or _pick_block(T, 256)
    assert B % block_b == 0, f"B={B} not divisible by block_b={block_b}"
    assert T % block_t == 0, f"T={T} not divisible by block_t={block_t}"
    grid = (B // block_b, T // block_t)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, S + 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, S, D), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_t,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_b, block_t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, T), coeffs.dtype),
        interpret=interpret,
    )(breaks, coeffs, ts)


def pad_pwpoly(breaks_list, coeffs_list, S, D, dtype=jnp.float32):
    """Pack a ragged list of piecewise polynomials into the padded [B, S+1] /
    [B, S, D] arrays the kernel expects. Each element of ``breaks_list`` is a
    1-D array of piece starts (k+1 entries incl. the final break, which may
    be inf) and ``coeffs_list[i]`` is [k, d] local coefficients.
    """
    import numpy as np

    B = len(breaks_list)
    breaks = np.full((B, S + 1), BIG, dtype=np.float64)
    coeffs = np.zeros((B, S, D), dtype=np.float64)
    for i, (bk, cf) in enumerate(zip(breaks_list, coeffs_list)):
        bk = np.asarray(bk, dtype=np.float64)
        cf = np.atleast_2d(np.asarray(cf, dtype=np.float64))
        k = cf.shape[0]
        d = cf.shape[1]
        assert k <= S, f"{k} pieces > padded S={S}"
        assert d <= D, f"degree+1 {d} > padded D={D}"
        bk = np.where(np.isfinite(bk), bk, BIG)
        breaks[i, : k + 1] = bk[: k + 1]
        # replicate the last piece into the padding so clamp-right works:
        # padded pieces start at BIG and are never selected anyway
        coeffs[i, :k, :d] = cf
        if k < S:
            # padded pieces: constant extension of the last piece's value at
            # its start (never selected because their start is BIG)
            coeffs[i, k:, 0] = 0.0
    return (
        jnp.asarray(breaks, dtype=dtype),
        jnp.asarray(coeffs, dtype=dtype),
    )
