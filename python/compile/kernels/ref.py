"""Pure-numpy correctness oracle for the Pallas kernel and the grid solver.

Implements the same piecewise-polynomial semantics as
``pwpoly_eval.pwpoly_eval`` with an entirely different mechanism
(searchsorted + polyval per batch element, no one-hot tricks), so agreement
is a meaningful signal. Used by pytest + hypothesis.
"""

import numpy as np


def pwpoly_eval_ref(breaks, coeffs, ts):
    """Reference evaluation.

    breaks: [B, S+1], coeffs: [B, S, D], ts: [T]  ->  [B, T] (float64)
    """
    breaks = np.asarray(breaks, dtype=np.float64)
    coeffs = np.asarray(coeffs, dtype=np.float64)
    ts = np.asarray(ts, dtype=np.float64)
    B, S = coeffs.shape[0], coeffs.shape[1]
    out = np.zeros((B, len(ts)))
    for b in range(B):
        starts = breaks[b, :S]
        inner = breaks[b, 1:S]
        # right-continuous piece index
        idx = np.searchsorted(inner, ts, side="right")
        tc = np.maximum(ts, starts[0])  # clamp-left
        u = tc - starts[idx]
        # horner, highest degree first (np.polyval wants descending)
        for j, (i, uu) in enumerate(zip(idx, u)):
            out[b, j] = np.polyval(coeffs[b, i, ::-1], uu)
    return out


def grid_solve_ref(pd, rbreaks, rslopes, rin, ts, target):
    """Reference for the L2 grid solver (model.grid_solve_pd semantics).

    pd:      [B, K, T] data-progress grids
    rbreaks: [B, L, S2+1] piece starts of R'_Rl in p
    rslopes: [B, L, S2]   piecewise-constant R' values
    rin:     [B, L, T]    allocation rates on the grid
    ts:      [T]
    target:  [B]
    ->  P [B, T], makespan [B] (inf when unreached)
    """
    pd = np.asarray(pd, dtype=np.float64)
    rin = np.asarray(rin, dtype=np.float64)
    ts = np.asarray(ts, dtype=np.float64)
    target = np.asarray(target, dtype=np.float64)
    rbreaks = np.asarray(rbreaks, dtype=np.float64)
    rslopes = np.asarray(rslopes, dtype=np.float64)
    B, _K, T = pd.shape
    L, S2 = rslopes.shape[1], rslopes.shape[2]
    dt = ts[1] - ts[0]
    pdmin = pd.min(axis=1)
    P = np.zeros((B, T))
    P[:, 0] = np.maximum(np.minimum(pdmin[:, 0], 0.0), 0.0)
    for t in range(1, T):
        for b in range(B):
            p = P[b, t - 1]
            dp = np.inf
            for l in range(L):
                inner = rbreaks[b, l, 1:S2]
                i = np.searchsorted(inner, p, side="right")
                c = rslopes[b, l, i]
                if c > 1e-20:
                    dp = min(dp, rin[b, l, t - 1] * dt / c)
            nxt = p + max(dp, 0.0)
            P[b, t] = max(min(pdmin[b, t], nxt), p)
    makespan = np.full(B, np.inf)
    for b in range(B):
        reached = P[b] >= target[b] * (1.0 - 1e-6)
        if reached.any():
            makespan[b] = ts[int(np.argmax(reached))]
    return P, makespan
