"""L2 grid solver vs. the numpy reference and vs. known analytic solutions.

The semantic test cases mirror `rust/src/solver/exact.rs` tests, so the
batched JAX solver, the numpy reference AND the exact Rust solver all agree
on the same scenarios — three independent implementations.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.pwpoly_eval import BIG
from compile.kernels.ref import grid_solve_ref
from compile.model import grid_solve, grid_solve_pd, resource_usage_grid


def as_f32(*arrays):
    return [jnp.asarray(a, jnp.float32) for a in arrays]


def run_pd(pd, rbreaks, rslopes, rin, ts, target):
    P, mk = grid_solve_pd(*as_f32(pd, rbreaks, rslopes, rin, ts, target))
    return np.asarray(P, np.float64), np.asarray(mk, np.float64)


def simple_resources(B, L, slopes):
    """Single-piece R' per resource: rbreaks [0, BIG...], rslopes given."""
    rbreaks = np.full((B, L, 5), BIG)
    rbreaks[:, :, 0] = 0.0
    rslopes = np.zeros((B, L, 4))
    for l, s in enumerate(slopes):
        rslopes[:, l, 0] = s
    return rbreaks, rslopes


def test_cpu_bound_stream():
    # mirror of rust cpu_bound_stream: 100 progress, 0.5 cpu/progress,
    # 1 cpu/s -> finish at 50
    B, K, L, T = 2, 1, 1, 512
    ts = np.linspace(0, 80, T)
    pd = np.full((B, K, T), 100.0)
    rbreaks, rslopes = simple_resources(B, L, [0.5])
    rin = np.ones((B, L, T))
    target = np.full(B, 100.0)
    P, mk = run_pd(pd, rbreaks, rslopes, rin, ts, target)
    assert abs(mk[0] - 50.0) < 0.5, mk
    i25 = np.argmin(np.abs(ts - 25.0))
    assert abs(P[0, i25] - 50.0) < 1.0


def test_data_bound_stream():
    # data envelope 1 progress/s, cpu ample -> finish at 100
    B, K, L, T = 1, 1, 1, 512
    ts = np.linspace(0, 150, T)
    pd = np.minimum(ts, 100.0)[None, None, :].repeat(B, 0)
    rbreaks, rslopes = simple_resources(B, L, [0.01])
    rin = np.ones((B, L, T))
    target = np.full(B, 100.0)
    _, mk = run_pd(pd, rbreaks, rslopes, rin, ts, target)
    assert abs(mk[0] - 100.0) < 0.5, mk


def test_crossover_case():
    # mirror of rust data_then_resource_crossover: finish at 110
    B, K, L, T = 1, 1, 1, 2048
    ts = np.linspace(0, 150, T)
    pd_curve = np.where(ts < 30, 2 * ts, np.minimum(60 + 0.5 * (ts - 30), 100.0))
    pd = pd_curve[None, None, :]
    rbreaks, rslopes = simple_resources(B, L, [1.0])
    rin = np.ones((B, L, T))
    target = np.full(B, 100.0)
    P, mk = run_pd(pd, rbreaks, rslopes, rin, ts, target)
    assert abs(mk[0] - 110.0) < 0.5, mk
    i90 = np.argmin(np.abs(ts - 90.0))
    assert abs(P[0, i90] - 90.0) < 1.0


def test_two_resources_min():
    # mirror two_resources_min: io limits -> finish at 100
    B, K, L, T = 1, 1, 2, 1024
    ts = np.linspace(0, 150, T)
    pd = np.full((B, K, T), 100.0)
    rbreaks, rslopes = simple_resources(B, L, [1.0, 0.5])
    rin = np.stack(
        [np.full((B, T), 2.0), np.full((B, T), 0.5)], axis=1
    )
    target = np.full(B, 100.0)
    _, mk = run_pd(pd, rbreaks, rslopes, rin, ts, target)
    assert abs(mk[0] - 100.0) < 0.5, mk


def test_unreached_is_inf():
    B, K, L, T = 1, 1, 1, 64
    ts = np.linspace(0, 10, T)
    pd = np.full((B, K, T), 50.0)  # data caps at 50
    rbreaks, rslopes = simple_resources(B, L, [1.0])
    rin = np.ones((B, L, T))
    target = np.full(B, 100.0)
    _, mk = run_pd(pd, rbreaks, rslopes, rin, ts, target)
    assert np.isinf(mk[0])


def test_piecewise_resource_requirement():
    # R' = 1 for p<50, 2 for p>=50; allocation 1/s
    # first 50 progress take 50 s, next 50 take 100 s -> 150 s
    B, K, L, T = 1, 1, 1, 2048
    ts = np.linspace(0, 200, T)
    pd = np.full((B, K, T), 100.0)
    rbreaks = np.full((B, L, 5), BIG)
    rbreaks[:, :, 0] = 0.0
    rbreaks[:, :, 1] = 50.0
    rslopes = np.zeros((B, L, 4))
    rslopes[:, :, 0] = 1.0
    rslopes[:, :, 1] = 2.0
    rin = np.ones((B, L, T))
    target = np.full(B, 100.0)
    _, mk = run_pd(pd, rbreaks, rslopes, rin, ts, target)
    assert abs(mk[0] - 150.0) < 0.7, mk


def test_kernel_path_grid_solve_matches_pd_path():
    # same scenario expressed as piecewise functions vs pre-sampled grids
    B, K, S, D, L, S2, T = 4, 2, 4, 3, 2, 4, 256
    ts = np.linspace(0, 120, T).astype(np.float64)
    # data input: ramp slope 1 capped at 100 (K=1 real + 1 padding)
    breaks_d = np.full((B, K, S + 1), BIG)
    coeffs_d = np.zeros((B, K, S, D))
    breaks_d[:, 0, 0] = 0.0
    breaks_d[:, 0, 1] = 100.0
    coeffs_d[:, 0, 0, 1] = 1.0  # ramp
    coeffs_d[:, 0, 1, 0] = 100.0  # then constant
    breaks_d[:, 1, 0] = 0.0
    coeffs_d[:, 1, 0, 0] = BIG  # padding input never binds
    rbreaks = np.full((B, L, S2 + 1), BIG)
    rbreaks[:, :, 0] = 0.0
    rslopes = np.zeros((B, L, S2))
    rslopes[:, 0, 0] = 0.8
    rin = np.ones((B, L, T))
    target = np.full(B, 100.0)

    P1, mk1 = grid_solve(
        *as_f32(breaks_d, coeffs_d, rbreaks, rslopes, rin, ts, target)
    )
    # sample pd by hand
    pd0 = np.minimum(np.maximum(ts, 0.0), 100.0)
    pd = np.stack(
        [np.tile(pd0, (B, 1)), np.full((B, T), BIG)], axis=1
    )
    P2, mk2 = grid_solve_pd(*as_f32(pd, rbreaks, rslopes, rin, ts, target))
    np.testing.assert_allclose(
        np.asarray(mk1), np.asarray(mk2), rtol=1e-5, atol=0.5
    )
    np.testing.assert_allclose(
        np.asarray(P1), np.asarray(P2), rtol=1e-4, atol=0.5
    )


def test_resource_usage_grid_bounded():
    B, K, L, T = 1, 1, 1, 256
    ts = np.linspace(0, 80, T)
    pd = np.full((B, K, T), 100.0)
    rbreaks, rslopes = simple_resources(B, L, [0.5])
    rin = np.ones((B, L, T))
    target = np.full(B, 100.0)
    P, _ = run_pd(pd, rbreaks, rslopes, rin, ts, target)
    usage = np.asarray(
        resource_usage_grid(
            jnp.asarray(P, jnp.float32),
            jnp.asarray(rbreaks, jnp.float32),
            jnp.asarray(rslopes, jnp.float32),
            jnp.asarray(ts, jnp.float32),
        )
    )
    # demand never exceeds allocation (paper eq. 7: usage in [0, 1])
    assert (usage <= rin * 1.02 + 1e-6).all()
    assert (usage >= -1e-6).all()


@st.composite
def solver_cases(draw):
    rng = np.random.default_rng(draw(st.integers(0, 2**32 - 1)))
    B = draw(st.sampled_from([1, 3]))
    K = draw(st.sampled_from([1, 2]))
    L = draw(st.sampled_from([1, 2]))
    T = 256
    span = 120.0
    ts = np.linspace(0.0, span, T)
    # monotone random data envelopes: cumsum of nonnegative rates
    rates = rng.uniform(0.0, 3.0, size=(B, K, T))
    pd = np.cumsum(rates, axis=2) * (span / T)
    rbreaks = np.full((B, L, 5), BIG)
    rbreaks[:, :, 0] = 0.0
    rslopes = np.zeros((B, L, 4))
    rslopes[:, :, 0] = rng.uniform(0.2, 2.0, size=(B, L))
    # piecewise-constant allocations
    rin = rng.uniform(0.0, 2.0, size=(B, L, 4)).repeat(T // 4, axis=2)
    target = pd.min(axis=1).max(axis=1) * rng.uniform(0.5, 1.1, size=B)
    return pd, rbreaks, rslopes, rin, ts, target


@settings(max_examples=25, deadline=None)
@given(solver_cases())
def test_grid_solver_matches_numpy_ref(case):
    pd, rbreaks, rslopes, rin, ts, target = case
    P, mk = run_pd(pd, rbreaks, rslopes, rin, ts, target)
    P_ref, mk_ref = grid_solve_ref(pd, rbreaks, rslopes, rin, ts, target)
    scale = np.maximum(1.0, np.abs(P_ref))
    np.testing.assert_allclose(P / scale, P_ref / scale, rtol=2e-3, atol=2e-3)
    both_inf = np.isinf(mk) & np.isinf(mk_ref)
    np.testing.assert_allclose(
        np.where(both_inf, 0.0, mk), np.where(both_inf, 0.0, mk_ref), atol=1.0
    )
