"""AOT export sanity: every artifact lowers, parses as HLO text, and the
manifest matches the entry specs."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

from compile import aot


def test_entries_cover_required_artifacts():
    names = [name for name, _, _ in aot.build_entries()]
    assert any(n.startswith("eval_pw_") for n in names)
    assert any(n.startswith("grid_solve_b") for n in names)
    # both the sweep-size and the small test variant of the pd solver
    assert "grid_solve_pd_b600_k2_l2_s4_t2048" in names
    assert "grid_solve_pd_b8_k2_l2_s4_t256" in names


def test_lowering_produces_hlo_text():
    # lower only the small variant (fast) and check the HLO text shape
    entries = [e for e in aot.build_entries() if "pd_b8" in e[0]]
    assert entries
    name, fn, specs = entries[0]
    import jax

    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # the scan lowers to a while loop
    assert "while" in text


def test_main_writes_manifest(tmp_path):
    rc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--only", "pd_b8"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
    )
    assert rc.returncode == 0, rc.stderr
    manifest = json.load(open(tmp_path / "manifest.json"))
    assert "grid_solve_pd_b8_k2_l2_s4_t256" in manifest
    entry = manifest["grid_solve_pd_b8_k2_l2_s4_t256"]
    assert (tmp_path / entry["file"]).exists()
    assert entry["inputs"][0] == [8, 2, 256]


def test_pallas_kernel_in_grid_solve_hlo():
    # the kernel path artifact must contain the one-hot/iota machinery of
    # the pallas kernel body (interpret=True lowers to plain HLO ops)
    entries = [e for e in aot.build_entries() if e[0].startswith("grid_solve_b")]
    name, fn, specs = entries[0]
    import jax

    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "iota" in text.lower()
    assert "while" in text  # the scan
