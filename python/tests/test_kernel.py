"""Pallas kernel vs. the pure-numpy oracle — the core L1 correctness signal.

Hypothesis sweeps random piecewise polynomials (shapes, piece counts,
degrees, breakpoints) and asserts allclose against ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.pwpoly_eval import BIG, pad_pwpoly, pwpoly_eval
from compile.kernels.ref import pwpoly_eval_ref


def run_kernel(breaks, coeffs, ts):
    import jax.numpy as jnp

    out = pwpoly_eval(
        jnp.asarray(breaks, jnp.float32),
        jnp.asarray(coeffs, jnp.float32),
        jnp.asarray(ts, jnp.float32),
    )
    return np.asarray(out, dtype=np.float64)


def assert_matches_ref(breaks, coeffs, ts, rtol=2e-4, atol=2e-3):
    got = run_kernel(breaks, coeffs, ts)
    want = pwpoly_eval_ref(breaks, coeffs, ts)
    scale = np.maximum(1.0, np.abs(want))
    np.testing.assert_allclose(got / scale, want / scale, rtol=rtol, atol=atol)


def test_constant_function():
    breaks = np.array([[0.0, BIG]] * 4 + [[1.0, BIG]] * 4)
    coeffs = np.zeros((8, 1, 1))
    coeffs[:, 0, 0] = np.arange(8)
    ts = np.linspace(0.0, 10.0, 16)
    assert_matches_ref(breaks, coeffs, ts)


def test_two_piece_linear_with_jump():
    # f = 2t on [0,5), then 100 (jump) on [5, inf)
    breaks = np.array([[0.0, 5.0, BIG]] * 8)
    coeffs = np.zeros((8, 2, 2))
    coeffs[:, 0, 1] = 2.0
    coeffs[:, 1, 0] = 100.0
    ts = np.linspace(0.0, 10.0, 32)
    got = run_kernel(breaks, coeffs, ts)
    assert abs(got[0, 0] - 0.0) < 1e-3
    # right-continuity at the break
    i5 = np.argmin(np.abs(ts - 5.0))
    if ts[i5] >= 5.0:
        assert abs(got[0, i5] - 100.0) < 1e-2
    assert_matches_ref(breaks, coeffs, ts)


def test_clamp_left_of_domain():
    breaks = np.array([[2.0, BIG]] * 8)
    coeffs = np.zeros((8, 1, 2))
    coeffs[:, 0, 0] = 7.0
    coeffs[:, 0, 1] = 1.0  # 7 + (t-2)
    ts = np.array([0.0, 1.0, 2.0, 3.0], dtype=np.float64)
    got = run_kernel(breaks, coeffs, ts)
    # left of the domain the value is clamped to f(2) = 7
    np.testing.assert_allclose(got[0], [7.0, 7.0, 7.0, 8.0], atol=1e-3)


def test_quadratic_piece():
    breaks = np.array([[0.0, 4.0, BIG]] * 8)
    coeffs = np.zeros((8, 2, 3))
    coeffs[:, 0, 2] = 0.25  # t^2/4
    coeffs[:, 1, 0] = 4.0  # then constant 4
    ts = np.linspace(0.0, 8.0, 64)
    assert_matches_ref(breaks, coeffs, ts)


def test_pad_pwpoly_roundtrip():
    breaks, coeffs = pad_pwpoly(
        [np.array([0.0, 2.0, np.inf]), np.array([1.0, np.inf])],
        [np.array([[0.0, 1.0], [2.0, 0.0]]), np.array([[5.0, 0.5]])],
        S=4,
        D=3,
    )
    assert breaks.shape == (2, 5)
    assert coeffs.shape == (2, 4, 3)
    ts = np.linspace(0.0, 5.0, 16)
    got = run_kernel(np.asarray(breaks), np.asarray(coeffs), ts)
    # function 0: t on [0,2), then 2 constant
    np.testing.assert_allclose(got[0, 0], 0.0, atol=1e-3)
    i = np.argmin(np.abs(ts - 3.0))
    np.testing.assert_allclose(got[0, i], 2.0, atol=1e-2)
    # function 1: 5 + 0.5*(t-1) from t=1, clamped to 5 before
    np.testing.assert_allclose(got[1, 0], 5.0, atol=1e-2)


@st.composite
def pwpoly_cases(draw):
    B = draw(st.sampled_from([1, 2, 4, 8]))
    S = draw(st.sampled_from([1, 2, 4, 8]))
    D = draw(st.integers(min_value=1, max_value=4))
    rng = np.random.default_rng(draw(st.integers(0, 2**32 - 1)))
    # strictly increasing finite breaks in [0, 100], last = BIG
    breaks = np.empty((B, S + 1))
    for b in range(B):
        cuts = np.sort(rng.uniform(0.0, 100.0, size=S))
        # enforce strict increase with a minimum gap
        cuts = cuts + np.arange(S) * 1e-3
        breaks[b, :S] = cuts
        breaks[b, S] = BIG
    coeffs = rng.uniform(-3.0, 3.0, size=(B, S, D))
    T = draw(st.sampled_from([8, 16, 64]))
    ts = np.sort(rng.uniform(-10.0, 150.0, size=T))
    return breaks, coeffs, ts


@settings(max_examples=40, deadline=None)
@given(pwpoly_cases())
def test_kernel_matches_ref_random(case):
    breaks, coeffs, ts = case
    # f32 kernel vs f64 ref: tolerance must account for catastrophic
    # cancellation in wide-range inputs; values here stay O(100)
    assert_matches_ref(breaks, coeffs, ts, rtol=1e-3, atol=5e-2)


@pytest.mark.parametrize("block_b,block_t", [(1, 8), (2, 4), (4, 16), (8, 8)])
def test_block_shapes_equivalent(block_b, block_t):
    rng = np.random.default_rng(7)
    B, S, D, T = 8, 4, 3, 16
    breaks = np.concatenate(
        [np.sort(rng.uniform(0, 50, (B, S))), np.full((B, 1), BIG)], axis=1
    )
    coeffs = rng.uniform(-2, 2, (B, S, D))
    ts = np.linspace(0, 60, T)
    import jax.numpy as jnp

    base = pwpoly_eval(
        jnp.asarray(breaks, jnp.float32),
        jnp.asarray(coeffs, jnp.float32),
        jnp.asarray(ts, jnp.float32),
    )
    tiled = pwpoly_eval(
        jnp.asarray(breaks, jnp.float32),
        jnp.asarray(coeffs, jnp.float32),
        jnp.asarray(ts, jnp.float32),
        block_b=block_b,
        block_t=block_t,
    )
    np.testing.assert_allclose(np.asarray(base), np.asarray(tiled), rtol=1e-6)
