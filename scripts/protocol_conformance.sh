#!/usr/bin/env bash
# Protocol conformance: every `>> request` / `<< response` pair embedded in
# docs/SERVICE.md is piped through a live `bottlemod serve` and the output
# diffed byte-for-byte, so the documented wire format cannot drift from the
# implementation.
#
# Usage (from the repo root, after `cargo build --release`):
#   bash scripts/protocol_conformance.sh [path/to/SERVICE.md]
# BOTTLEMOD_BIN overrides the binary under test.
set -euo pipefail

doc=${1:-docs/SERVICE.md}
bin=${BOTTLEMOD_BIN:-target/release/bottlemod}

if [ ! -x "$bin" ]; then
    echo "error: '$bin' is not built (run: cargo build --release)" >&2
    exit 1
fi

tmp=$(mktemp -d)
trap 'rm -rf "$tmp"' EXIT

sed -n 's/^>> //p' "$doc" > "$tmp/requests.jsonl"
sed -n 's/^<< //p' "$doc" > "$tmp/expected.jsonl"

req_n=$(wc -l < "$tmp/requests.jsonl")
exp_n=$(wc -l < "$tmp/expected.jsonl")
if [ "$req_n" -eq 0 ]; then
    echo "error: no '>>' conformance examples found in $doc" >&2
    exit 1
fi
if [ "$req_n" -ne "$exp_n" ]; then
    echo "error: $doc has $req_n '>>' requests but $exp_n '<<' responses" >&2
    exit 1
fi

# the corpus must keep exercising the session-scoped monitor lifecycle
# (open -> feed -> close and the out-of-lifecycle errors; docs/LIVE.md)
# plus the service-scoped stats op and the sensitivity decode guards
for op in monitor_open monitor_feed monitor_status stats sensitivity; do
    if ! grep -q "\"op\": \"$op\"" "$tmp/requests.jsonl"; then
        echo "error: conformance corpus in $doc lost its '$op' exchange" >&2
        exit 1
    fi
done

# single-threaded for fully deterministic cache counters (not that the
# corpus includes any — belt and braces)
BOTTLEMOD_THREADS=1 "$bin" serve < "$tmp/requests.jsonl" > "$tmp/got.jsonl"

if ! diff -u "$tmp/expected.jsonl" "$tmp/got.jsonl"; then
    echo "protocol conformance FAILED: $doc drifted from the live wire format" >&2
    exit 1
fi
echo "protocol conformance OK: $req_n documented exchanges match the live server"
