//! A genomics-flavoured workflow (the paper's intro motivates genome
//! analysis): a sequencer dump is downloaded, QC-filtered (stream), aligned
//! (burst per sample — the aligner builds an index over the full sample
//! first), and the variants are called from all alignments (burst join).
//! Two samples share the ingest link; alignment shares a CPU pool.
//!
//! Demonstrates: a larger DAG (8 processes), two shared pools, bottleneck
//! reporting across the whole workflow, and the advisor primitive on a
//! non-video scenario.
//!
//! Run: `cargo run --release --example genomics_pipeline`

use bottlemod::model::ProcessBuilder;
use bottlemod::pwfn::PwPoly;
use bottlemod::solver::SolverOpts;
use bottlemod::util::stats::ascii_table;
use bottlemod::workflow::engine::analyze_fixpoint;
use bottlemod::workflow::graph::{DataSource, ResourceSource, StartRule, Workflow};

const SAMPLE: f64 = 4e9; // 4 GB raw reads per sample
const FILTERED: f64 = 3e9; // QC keeps 75%
const BAM: f64 = 1.5e9; // alignment output
const VCF: f64 = 50e6; // called variants
const LINK: f64 = 100e6; // 100 MB/s ingest link
const CORES: f64 = 8.0; // shared CPU pool

fn build(frac_sample1: f64) -> (Workflow, Vec<usize>) {
    let mut wf = Workflow::new();
    let link = wf.add_pool("ingest-link", PwPoly::constant(LINK));
    let cpu = wf.add_pool("cpu", PwPoly::constant(CORES));
    let mut nodes = vec![];

    for s in 0..2 {
        // ingest: download the raw sample
        let dl = ProcessBuilder::new(&format!("ingest-s{s}"), SAMPLE)
            .stream_data("remote", SAMPLE)
            .stream_resource("link", SAMPLE)
            .identity_output("raw")
            .build();
        let dl_n = wf.add_node(
            dl,
            vec![DataSource::External(PwPoly::constant(SAMPLE))],
            vec![if s == 0 {
                ResourceSource::PoolFraction {
                    pool: link,
                    fraction: frac_sample1,
                }
            } else {
                ResourceSource::PoolResidual { pool: link }
            }],
            StartRule::default(),
        );

        // QC filter: pure stream, 120 CPU-s per sample, 2 cores granted
        let qc = ProcessBuilder::new(&format!("qc-s{s}"), FILTERED)
            .stream_data("raw", SAMPLE)
            .stream_resource("cpu", 120.0)
            .identity_output("filtered")
            .build();
        let qc_n = wf.add_node(
            qc,
            vec![DataSource::ProcessOutput {
                node: dl_n,
                output: 0,
            }],
            vec![ResourceSource::PoolFraction {
                pool: cpu,
                fraction: 2.0 / CORES,
            }],
            StartRule::default(),
        );

        // alignment: burst (index over the whole filtered sample first),
        // heavy CPU, granted 2 cores from the pool
        let align = ProcessBuilder::new(&format!("align-s{s}"), BAM)
            .burst_data("filtered", FILTERED)
            .stream_resource("cpu", 600.0)
            .identity_output("bam")
            .build();
        let align_n = wf.add_node(
            align,
            vec![DataSource::ProcessOutput {
                node: qc_n,
                output: 0,
            }],
            vec![ResourceSource::PoolFraction {
                pool: cpu,
                fraction: 2.0 / CORES,
            }],
            StartRule::default(),
        );
        nodes.extend([dl_n, qc_n, align_n]);
    }

    // joint variant calling over both alignments (burst join)
    let call = ProcessBuilder::new("call-variants", VCF)
        .burst_data("bam0", BAM)
        .burst_data("bam1", BAM)
        .stream_resource("cpu", 300.0)
        .identity_output("vcf")
        .build();
    let call_n = wf.add_node(
        call,
        vec![
            DataSource::ProcessOutput {
                node: nodes[2],
                output: 0,
            },
            DataSource::ProcessOutput {
                node: nodes[5],
                output: 0,
            },
        ],
        vec![ResourceSource::PoolFraction {
            pool: cpu,
            fraction: 1.0,
        }],
        StartRule {
            at: 0.0,
            after: vec![nodes[2], nodes[5]],
        },
    );
    nodes.push(call_n);

    // final report: quick stream over the VCF
    let report = ProcessBuilder::new("report", 1e6)
        .stream_data("vcf", VCF)
        .stream_resource("cpu", 5.0)
        .identity_output("html")
        .build();
    let rep_n = wf.add_node(
        report,
        vec![DataSource::ProcessOutput {
            node: call_n,
            output: 0,
        }],
        vec![ResourceSource::PoolFraction {
            pool: cpu,
            fraction: 1.0 / CORES,
        }],
        StartRule::default(),
    );
    nodes.push(rep_n);
    (wf, nodes)
}

fn main() -> anyhow::Result<()> {
    let opts = SolverOpts::default();

    // fair ingest split
    let (wf, _) = build(0.5);
    let wa = analyze_fixpoint(&wf, &opts, 6)?;
    println!("== genomics pipeline, fair ingest split ==");
    let mut rows = vec![vec![
        "process".into(),
        "start (s)".into(),
        "finish (s)".into(),
        "dominant bottleneck".into(),
    ]];
    for (i, a) in wa.analyses.iter().enumerate() {
        let p = &wf.nodes[i].process;
        // dominant = longest segment
        let dom = a
            .segments
            .iter()
            .max_by(|x, y| {
                (x.end - x.start).partial_cmp(&(y.end - y.start)).unwrap()
            })
            .map(|s| a.bottleneck_name(p, s.bottleneck))
            .unwrap_or_default();
        rows.push(vec![
            p.name.clone(),
            format!("{:.0}", a.start_time),
            format!("{:.0}", a.finish_time.unwrap_or(f64::NAN)),
            dom,
        ]);
    }
    print!("{}", ascii_table(&rows));
    println!("makespan: {:.0} s  ({} solver events)", wa.makespan.unwrap(), wa.events);

    // sweep the ingest split like the paper sweeps the link
    println!("\n== ingest-split sweep ==");
    let mut best = (0.5, f64::INFINITY);
    for i in 1..20 {
        let f = i as f64 / 20.0;
        let (wf, _) = build(f);
        let total = analyze_fixpoint(&wf, &opts, 6)?.makespan.unwrap();
        if total < best.1 {
            best = (f, total);
        }
    }
    let fair = wa.makespan.unwrap();
    println!(
        "best split {:.2} -> {:.0} s vs fair {:.0} s ({:+.1}%)",
        best.0,
        best.1,
        fair,
        (best.1 / fair - 1.0) * 100.0
    );
    Ok(())
}
